"""Session: THE driver loop. Every benchmark, example, and test drives a
backend through this one propose -> apply -> observe loop; the three
near-duplicate tick loops that used to live in benchmarks/common.py
(`run_static` / `run_optimizer` / `run_fleet_optimizer`) went through
their one-PR deprecation-shim stage here and are deleted.

    backend = SimBackend(spec, machine, seed=0)
    opt     = make_optimizer("intune", spec, machine, seed=0)
    result  = Session(backend, opt).run(600, events=[ResizeEvent(200, 64)])

Loop contract (kept bit-for-bit with the legacy loops so the fig5 golden
JSONs regenerate byte-identically through this path):

  - events due at tick t are injected before the tick's proposal, so
    policies propose against the post-event machine/fleet state;
  - the capacity a proposal is made against is read at propose time —
    reading it after apply would let a fleet's next-tick churn clamp this
    tick's used_cpus with t+1 capacity;
  - `relaunch_dead` > 0 charges a checkpoint+relaunch dead window
    whenever the proposal changes (static *-Adaptive policies; learning
    policies re-allocate live and pass 0). DeadWindow events schedule
    explicit down-time on top;
  - dead ticks advance the backend clock without applying anything, and
    the optimizer still observes the zero Telemetry (a restart is the
    strongest learning signal);
  - with no optimizer the backend must be self-driving
    (ControllerBackend): `apply(None)` each tick.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.api.backend import Backend
from repro.api.events import DeadWindow, Event
from repro.api.telemetry import RunResult, Telemetry


def _proposal_changed(alloc: Any, prev: Any) -> bool:
    """Allocation and FleetAllocation both expose the flattened
    workers/prefetch_mb views this compares on."""
    return (not np.array_equal(alloc.workers, prev.workers)
            or alloc.prefetch_mb != prev.prefetch_mb)


class FrozenPolicy:
    """The simplest Optimizer: always propose the given allocation (a
    pipeline configured once and never touched — the paper's frozen
    AUTOTUNE baseline, or any hand-set placement under test)."""

    name = "frozen"

    def __init__(self, alloc: Any) -> None:
        self.alloc = alloc

    def propose(self, spec: Any, machine: Any,
                stats: Optional[Dict[str, Any]] = None) -> Any:
        return self.alloc

    def observe(self, metrics: Telemetry) -> None:
        pass


class Session:
    """One runtime over one backend, optionally driven by an optimizer.

    `spec` defaults to the backend's own spec (StageGraph or ClusterSpec)
    and is what `optimizer.propose(spec, machine)` receives. Use as a
    context manager (or call `close()`) to tear live backends down.
    """

    def __init__(self, backend: Backend, optimizer: Optional[Any] = None,
                 *, spec: Optional[Any] = None) -> None:
        self.backend = backend
        self.optimizer = optimizer
        self.spec = spec if spec is not None \
            else getattr(backend, "spec", None)

    # ------------------------------------------------------------- loop ---
    def run(self, ticks: int, *, events: Optional[Sequence[Event]] = None,
            relaunch_dead: int = 0,
            collect: Optional[Callable[[int, Telemetry], None]] = None
            ) -> RunResult:
        sched: List[Event] = sorted(events or [], key=lambda e: e.tick)
        nxt = 0
        dead = 0
        prev = None
        res = RunResult()
        for t in range(ticks):
            while nxt < len(sched) and sched[nxt].tick <= t:
                ev = sched[nxt]
                nxt += 1
                if isinstance(ev, DeadWindow):
                    dead = max(dead, int(ev.ticks))
                else:
                    self.backend.inject(ev)
            if self.optimizer is not None:
                # live backends supply measured stats (None from analytic
                # ones), so learning policies act on the same source they
                # observe through
                alloc = self.optimizer.propose(self.spec,
                                               self.backend.machine,
                                               self.backend.stats())
                cap = self.backend.capacity
                if relaunch_dead and prev is not None \
                        and _proposal_changed(alloc, prev):
                    # max: a relaunch never truncates a longer scheduled
                    # DeadWindow already in progress
                    dead = max(dead, relaunch_dead)
                prev = alloc
            else:
                alloc = None
                cap = self.backend.capacity
            if dead > 0:
                dead -= 1
                tel = self.backend.skip_tick()
            else:
                tel = self.backend.apply(alloc)
            if self.optimizer is not None:
                self.optimizer.observe(tel)
            if collect is not None:
                collect(t, tel)
            res.throughput.append(tel.throughput)
            res.used_cpus.append(min(tel.used_cpus, cap))
            res.mem_mb.append(tel.mem_mb)
        res.oom_count = self.backend.oom_count
        if self.optimizer is not None:
            res.extras["optimizer"] = self.optimizer
        return res

    # ------------------------------------------------------ train-driven --
    def step(self, tel: Optional[Telemetry] = None) -> Telemetry:
        """One tuning tick driven by an EXTERNAL clock (a train loop):
        measure the window that just ran, let the optimizer observe it,
        then propose + apply the next allocation. A caller that already
        measured (e.g. to inspect the `settling` flag before deciding to
        tune) passes that Telemetry in; otherwise the backend measures.

        The ordering matters for learning optimizers: `observe` must see
        the telemetry produced UNDER the previously-applied allocation
        (its pending action), and the new proposal is applied before the
        caller runs the next batch of train steps — so every (action,
        outcome) pair the agent learns from is causally aligned. Call
        between train steps:

            for step in range(n_steps):
                batch = next(feed)
                state = train_step(state, batch)
                if step % tune_every == 0:
                    tel = session.step()   # tune against measured idle

        Backends without a `measure()` method (everything but
        FeedBackend) fall back to `apply(None)` for the measurement,
        which analytic/self-driving backends treat as a plain tick.
        """
        if tel is None:
            measure = getattr(self.backend, "measure", None)
            tel = measure() if callable(measure) \
                else self.backend.apply(None)
        if self.optimizer is not None:
            self.optimizer.observe(tel)
            alloc = self.optimizer.propose(self.spec, self.backend.machine,
                                           self.backend.stats())
            self.backend.apply(alloc)
        return tel

    # --------------------------------------------------------- lifecycle --
    def close(self) -> Dict[str, Any]:
        return self.backend.shutdown()

    def __enter__(self) -> "Session":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.close()
