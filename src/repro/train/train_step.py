"""Generic train/eval step builders.

make_train_step wires loss -> grad -> optimizer into a single jit-able
function; microbatching (gradient accumulation via lax.scan) is built in —
the memory knob the §Perf hillclimbs use on the train_4k cells.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.train.optim import Optimizer


def make_train_step(loss_fn: Callable, optimizer: Optimizer,
                    microbatches: int = 1):
    """loss_fn(params, batch) -> (loss, metrics dict).

    Returns train_step(params, opt_state, step, batch) ->
    (params, opt_state, metrics). With microbatches > 1, the batch's leading
    axis is split and gradients averaged via a scan (activation memory drops
    ~linearly; the optimizer still sees one global step).
    """
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def one(params, batch):
        (loss, metrics), grads = grad_fn(params, batch)
        return loss, metrics, grads

    def train_step(params, opt_state, step, batch):
        if microbatches == 1:
            loss, metrics, grads = one(params, batch)
        else:
            def split(x):
                b = x.shape[0]
                assert b % microbatches == 0, (b, microbatches)
                return x.reshape((microbatches, b // microbatches)
                                 + x.shape[1:])
            mb = jax.tree_util.tree_map(split, batch)

            def body(acc, mbatch):
                loss, metrics, grads = one(params, mbatch)
                acc_g, acc_l = acc
                acc_g = jax.tree_util.tree_map(
                    lambda a, g: a + g.astype(jnp.float32) / microbatches,
                    acc_g, grads)
                return (acc_g, acc_l + loss / microbatches), metrics

            zero_g = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), metrics_stack = jax.lax.scan(
                body, (zero_g, jnp.zeros((), jnp.float32)), mb)
            metrics = jax.tree_util.tree_map(jnp.mean, metrics_stack)

        new_params, new_opt, stats = optimizer.update(
            grads, opt_state, params, step)
        out = dict(metrics)
        out.update(stats)
        out["loss"] = loss
        return new_params, new_opt, out

    return train_step


def make_eval_step(loss_fn: Callable):
    def eval_step(params, batch):
        loss, metrics = loss_fn(params, batch)
        out = dict(metrics)
        out["loss"] = loss
        return out
    return eval_step
