"""Optimizers in pure JAX (no optax): SGD-momentum, Adam(W), Adagrad,
Adafactor.

Adafactor (factored second moments) is what makes the 1T-param kimi-k2
config fit HBM: per-matrix state is O(rows + cols) instead of O(rows*cols).
Adagrad is the classic DLRM embedding optimizer.

API:
    opt = make_optimizer("adam", lr=1e-3, ...)
    state = opt.init(params)
    params, state, stats = opt.update(grads, state, params, step)

`opt_logical_axes(name, params_logical)` returns the logical-axis tree for
the optimizer state so it shards exactly like the parameters it mirrors.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.common.pytree import global_norm


class Optimizer(NamedTuple):
    name: str
    init: Callable[[Any], Any]
    update: Callable[..., Any]   # (grads, state, params, step) -> (p, s, stats)


# Tensors bigger than this (elements) get their optimizer update scanned
# over axis 0 (layer-stacked weights): caps the f32 transient working set at
# one slice instead of the whole 5-GiB expert slab. Without this, the
# elementwise f32 chains (g32, g^2, u, p32) for the 1T-param configs
# dominate peak memory (observed ~40 GiB/device on kimi-k2 train).
_CHUNK_ELEMS = 1 << 26


def _chunked(fn, p, g, *states):
    """Apply fn(p_slice, g_slice, *state_slices) -> tuple, scanning over
    axis 0 for huge stacked tensors; otherwise apply directly."""
    if p.ndim < 3 or p.size <= _CHUNK_ELEMS:
        return fn(p, g, *states)

    def body(_, xs):
        return None, fn(*xs)
    _, out = jax.lax.scan(body, None, (p, g) + states)
    return out


# ------------------------------------------------------------ schedules ----
def warmup_cosine(base_lr: float, warmup: int, total: int,
                  min_frac: float = 0.1):
    def lr_at(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * (min_frac + (1 - min_frac) * 0.5 *
                         (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup, warm, cos)
    return lr_at


def constant_lr(base_lr: float):
    return lambda step: jnp.asarray(base_lr, jnp.float32)


# ------------------------------------------------------------- clipping ----
def clip_by_global_norm(grads, max_norm: float):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), gn


# ------------------------------------------------------------------ sgd ----
def sgd(lr_fn, momentum: float = 0.9, grad_clip: float = 0.0):
    def init(params):
        return {"mu": jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)}

    def update(grads, state, params, step):
        gn = global_norm(grads)
        if grad_clip:
            grads, _ = clip_by_global_norm(grads, grad_clip)
        lr = lr_fn(step)
        mu = jax.tree_util.tree_map(
            lambda m, g: momentum * m + g.astype(jnp.float32),
            state["mu"], grads)
        new_p = jax.tree_util.tree_map(
            lambda p, m: (p.astype(jnp.float32) - lr * m).astype(p.dtype),
            params, mu)
        return new_p, {"mu": mu}, {"lr": lr, "grad_norm": gn}
    return Optimizer("sgd", init, update)


# ----------------------------------------------------------------- adam ----
def adam(lr_fn, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
         weight_decay: float = 0.0, grad_clip: float = 1.0,
         state_dtype=jnp.float32):
    """AdamW. state_dtype=bfloat16 halves state memory (documented loss of
    precision — a large-model knob, not the default)."""
    def init(params):
        z = lambda p: jnp.zeros(p.shape, state_dtype)
        return {"m": jax.tree_util.tree_map(z, params),
                "v": jax.tree_util.tree_map(z, params)}

    def update(grads, state, params, step):
        gn = global_norm(grads)
        if grad_clip:
            grads, _ = clip_by_global_norm(grads, grad_clip)
        lr = lr_fn(step)
        t = jnp.asarray(step, jnp.float32) + 1.0
        bc1 = 1.0 - b1 ** t
        bc2 = 1.0 - b2 ** t

        def leaf(p, g, m, v):
            g32 = g.astype(jnp.float32)
            m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
            v32 = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g32)
            step_ = lr * (m32 / bc1) / (jnp.sqrt(v32 / bc2) + eps)
            p32 = p.astype(jnp.float32)
            if weight_decay:
                step_ = step_ + lr * weight_decay * p32
            return ((p32 - step_).astype(p.dtype), m32.astype(state_dtype),
                    v32.astype(state_dtype))

        p_leaves, treedef = jax.tree_util.tree_flatten(params)
        g_leaves = treedef.flatten_up_to(grads)
        m_leaves = treedef.flatten_up_to(state["m"])
        v_leaves = treedef.flatten_up_to(state["v"])
        new_p, new_m, new_v = [], [], []
        for p, g, m, v in zip(p_leaves, g_leaves, m_leaves, v_leaves):
            np_, nm, nv = _chunked(leaf, p, g, m, v)
            new_p.append(np_)
            new_m.append(nm)
            new_v.append(nv)
        unf = lambda leaves: jax.tree_util.tree_unflatten(treedef, leaves)
        return unf(new_p), {"m": unf(new_m), "v": unf(new_v)}, \
            {"lr": lr, "grad_norm": gn}
    return Optimizer("adam", init, update)


# -------------------------------------------------------------- adagrad ----
def adagrad(lr_fn, eps: float = 1e-10, grad_clip: float = 0.0):
    """Classic DLRM embedding optimizer."""
    def init(params):
        return {"acc": jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)}

    def update(grads, state, params, step):
        gn = global_norm(grads)
        if grad_clip:
            grads, _ = clip_by_global_norm(grads, grad_clip)
        lr = lr_fn(step)

        def leaf(p, g, a):
            g32 = g.astype(jnp.float32)
            a32 = a + jnp.square(g32)
            return ((p.astype(jnp.float32)
                     - lr * g32 / (jnp.sqrt(a32) + eps)).astype(p.dtype),
                    a32)

        p_leaves, treedef = jax.tree_util.tree_flatten(params)
        g_leaves = treedef.flatten_up_to(grads)
        a_leaves = treedef.flatten_up_to(state["acc"])
        new_p, new_a = [], []
        for p, g, a in zip(p_leaves, g_leaves, a_leaves):
            np_, na = _chunked(leaf, p, g, a)
            new_p.append(np_)
            new_a.append(na)
        unf = lambda leaves: jax.tree_util.tree_unflatten(treedef, leaves)
        return unf(new_p), {"acc": unf(new_a)}, {"lr": lr, "grad_norm": gn}
    return Optimizer("adagrad", init, update)


# ------------------------------------------------------ rowwise adagrad ----
def rowwise_adagrad(lr_fn, eps: float = 1e-10,
                    rowwise_min_elems: int = 1 << 24):
    """FBGEMM-style row-wise Adagrad: embedding-table leaves (huge, >=2D)
    keep ONE accumulator scalar per row (mean of squared grads over the
    embedding dim) — 1/dim the state of elementwise adagrad, the standard
    DLRM memory trick. Small/dense leaves use elementwise adagrad."""
    def _rowwise(p):
        return p.ndim >= 2 and p.size > rowwise_min_elems

    def init(params):
        def per(p):
            shape = p.shape[:-1] if _rowwise(p) else p.shape
            return jnp.zeros(shape, jnp.float32)
        return {"acc": jax.tree_util.tree_map(per, params)}

    def update(grads, state, params, step):
        gn = global_norm(grads)
        lr = lr_fn(step)

        def leaf(p, g, a):
            g32 = g.astype(jnp.float32)
            if a.shape != p.shape:             # row-wise
                a32 = a + jnp.mean(jnp.square(g32), axis=-1)
                scale = jax.lax.rsqrt(a32 + eps)[..., None]
            else:
                a32 = a + jnp.square(g32)
                scale = jax.lax.rsqrt(a32 + eps)
            return (p.astype(jnp.float32) - lr * g32 * scale).astype(
                p.dtype), a32

        p_leaves, treedef = jax.tree_util.tree_flatten(params)
        g_leaves = treedef.flatten_up_to(grads)
        a_leaves = treedef.flatten_up_to(state["acc"])
        new_p, new_a = [], []
        for p, g, a in zip(p_leaves, g_leaves, a_leaves):
            np_, na = _chunked(leaf, p, g, a)
            new_p.append(np_)
            new_a.append(na)
        unf = lambda leaves: jax.tree_util.tree_unflatten(treedef, leaves)
        return unf(new_p), {"acc": unf(new_a)}, {"lr": lr, "grad_norm": gn}
    return Optimizer("rowwise_adagrad", init, update)


# ------------------------------------------------------------ adafactor ----
def adafactor(lr_fn, decay: float = 0.8, eps1: float = 1e-30,
              eps2: float = 1e-3, clip_threshold: float = 1.0,
              min_dim_factored: int = 128):
    """Adafactor (Shazeer & Stern 2018), factored for params with both of the
    last two dims >= min_dim_factored; small params keep a full 2nd moment.
    No first moment (momentum=0), matching the memory-lean configuration."""
    def _factored(p):
        return p.ndim >= 2 and p.shape[-1] >= min_dim_factored \
            and p.shape[-2] >= min_dim_factored

    def init(params):
        def per(p):
            if _factored(p):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"f": jax.tree_util.tree_map(per, params)}

    # state leaves are dicts, so flatten against the params treedef.
    def update(grads, state, params, step):
        gn = global_norm(grads)
        lr = lr_fn(step)
        t = jnp.asarray(step, jnp.float32) + 1.0
        beta = 1.0 - t ** (-decay)

        def leaf_factored(p, g, vr_old, vc_old):
            g32 = g.astype(jnp.float32)
            g2 = jnp.square(g32) + eps1
            vr = beta * vr_old + (1 - beta) * jnp.mean(g2, axis=-1)
            vc = beta * vc_old + (1 - beta) * jnp.mean(g2, axis=-2)
            r = vr / jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), eps1)
            u = g32 / (jnp.sqrt(r)[..., None]
                       * jnp.sqrt(vc)[..., None, :] + eps1)
            # NOTE: under chunked updates, update-clipping RMS and the
            # param scale are per-layer-slice (a mild, documented variation)
            rms_u = jnp.sqrt(jnp.mean(jnp.square(u)) + eps1)
            u = u / jnp.maximum(1.0, rms_u / clip_threshold)
            p32 = p.astype(jnp.float32)
            scale = jnp.maximum(eps2, jnp.sqrt(jnp.mean(jnp.square(p32))))
            return (p32 - lr * scale * u).astype(p.dtype), vr, vc

        def leaf_full(p, g, v_old):
            g32 = g.astype(jnp.float32)
            g2 = jnp.square(g32) + eps1
            v = beta * v_old + (1 - beta) * g2
            u = g32 / (jnp.sqrt(v) + eps1)
            rms_u = jnp.sqrt(jnp.mean(jnp.square(u)) + eps1)
            u = u / jnp.maximum(1.0, rms_u / clip_threshold)
            p32 = p.astype(jnp.float32)
            scale = jnp.maximum(eps2, jnp.sqrt(jnp.mean(jnp.square(p32))))
            return (p32 - lr * scale * u).astype(p.dtype), v

        p_leaves, treedef = jax.tree_util.tree_flatten(params)
        g_leaves = treedef.flatten_up_to(grads)
        s_leaves = treedef.flatten_up_to(state["f"])
        new_p, new_s = [], []
        for p, g, s in zip(p_leaves, g_leaves, s_leaves):
            if "vr" in s:
                np_, vr, vc = _chunked(leaf_factored, p, g, s["vr"], s["vc"])
                new_s.append({"vr": vr, "vc": vc})
            else:
                np_, v = _chunked(leaf_full, p, g, s["v"])
                new_s.append({"v": v})
            new_p.append(np_)
        return (jax.tree_util.tree_unflatten(treedef, new_p),
                {"f": jax.tree_util.tree_unflatten(treedef, new_s)},
                {"lr": lr, "grad_norm": gn})

    return Optimizer("adafactor", init, update)


# -------------------------------------------------------------- factory ----
def make_optimizer(name: str, *, lr: float = 1e-3, total_steps: int = 10000,
                   warmup: int = 100, **kw) -> Optimizer:
    lr_fn = warmup_cosine(lr, warmup, total_steps)
    if name == "sgd":
        return sgd(lr_fn, **kw)
    if name == "adam":
        return adam(lr_fn, **kw)
    if name == "adagrad":
        return adagrad(lr_fn, **kw)
    if name == "rowwise_adagrad":
        return rowwise_adagrad(lr_fn, **kw)
    if name == "adafactor":
        return adafactor(lr_fn, **kw)
    raise ValueError(f"unknown optimizer {name!r}")


def opt_logical_axes(name: str, params_logical, params=None,
                     min_dim_factored: int = 128):
    """Logical-axis tree for optimizer state, mirroring the params tree."""
    if name == "sgd":
        return {"mu": params_logical}
    if name == "adam":
        return {"m": params_logical, "v": params_logical}
    if name == "adagrad":
        return {"acc": params_logical}
    if name == "rowwise_adagrad":
        assert params is not None, "rowwise axes need param shapes"
        p_leaves, treedef = jax.tree_util.tree_flatten(params)
        lg_leaves = treedef.flatten_up_to(params_logical)
        out = []
        for p, lg in zip(p_leaves, lg_leaves):
            lg = tuple(lg)
            out.append(lg[:-1] if p.ndim >= 2 and p.size > (1 << 24)
                       else lg)
        return {"acc": jax.tree_util.tree_unflatten(treedef, out)}
    if name == "adafactor":
        assert params is not None, "adafactor axes need param shapes"
        p_leaves, treedef = jax.tree_util.tree_flatten(params)
        lg_leaves = treedef.flatten_up_to(params_logical)
        out = []
        for p, lg in zip(p_leaves, lg_leaves):
            lg = tuple(lg)
            if p.ndim >= 2 and p.shape[-1] >= min_dim_factored \
                    and p.shape[-2] >= min_dim_factored:
                out.append({"vr": lg[:-1], "vc": lg[:-2] + lg[-1:]})
            else:
                out.append({"v": lg})
        return {"f": jax.tree_util.tree_unflatten(treedef, out)}
    raise ValueError(name)
