"""Fault-tolerant checkpointing: atomic shard files + JSON manifest.

Layout (one directory per step):
    <dir>/step_000123/
        manifest.json      step, timestamp, tree structure, mesh, extras
        shard_00000.npz    flattened path->array (host 0's slice set)
        ...
Writes go to `step_XXXX.tmp/` then a single atomic rename — a crash
mid-write never corrupts the latest-complete checkpoint, and `restore()`
always resolves the newest *complete* step. Arrays bigger than
`max_shard_bytes` are split across shard files along axis 0 so restore can
stream them host-parallel (the 1000-node story: shard count scales with
hosts, each host writes/reads only its files).

The InTune controller's state (agent weights, replay buffer, current CPU
allocation) rides along in `extras` so a restarted job resumes both model
AND pipeline tuning — the paper's rescale-recovery scenario.
"""
from __future__ import annotations

import json
import os
import shutil
import time
from typing import Any, Optional

import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}[{i}]/"))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten(flat):
    root: dict = {}
    for path, v in flat.items():
        keys = path.split("/")
        node = root
        for k in keys[:-1]:
            node = node.setdefault(k, {})
        node[keys[-1]] = v

    def fix(node):
        if not isinstance(node, dict):
            return node
        if node and all(k.startswith("[") for k in node):
            items = sorted(node.items(), key=lambda kv: int(kv[0][1:-1]))
            return tuple(fix(v) for _, v in items)
        return {k: fix(v) for k, v in node.items()}
    return fix(root)


def save(ckpt_dir: str, step: int, tree, *, extras: Optional[dict] = None,
         max_shard_bytes: int = 1 << 30) -> str:
    """Atomic checkpoint write. Returns the final directory path."""
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    flat = {k: np.asarray(v) for k, v in _flatten(tree).items()}
    shards: list[dict] = [{}]
    sizes = [0]
    index = {}   # path -> [(shard_id, axis0_start, axis0_end)]
    for path, arr in flat.items():
        if arr.nbytes > max_shard_bytes and arr.ndim >= 1 and arr.shape[0] > 1:
            n_chunks = -(-arr.nbytes // max_shard_bytes)
            rows = -(-arr.shape[0] // n_chunks)
            entries = []
            for s in range(0, arr.shape[0], rows):
                e = min(s + rows, arr.shape[0])
                shards.append({f"{path}@@{s}": arr[s:e]})
                sizes.append(arr[s:e].nbytes)
                entries.append([len(shards) - 1, s, e])
            index[path] = entries
        else:
            if sizes[-1] + arr.nbytes > max_shard_bytes and shards[-1]:
                shards.append({})
                sizes.append(0)
            shards[-1][path] = arr
            sizes[-1] += arr.nbytes
            index[path] = [[len(shards) - 1, -1, -1]]

    for i, shard in enumerate(shards):
        if shard:
            np.savez(os.path.join(tmp, f"shard_{i:05d}.npz"), **shard)
    manifest = {
        "step": step,
        "time": time.time(),
        "n_shards": len(shards),
        "index": index,
        "extras": extras or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic publish
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp") \
                and os.path.exists(os.path.join(ckpt_dir, name,
                                                "manifest.json")):
            steps.append(int(name[5:]))
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: Optional[int] = None):
    """Returns (tree, manifest). Raises FileNotFoundError if nothing valid."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    shard_cache: dict[int, Any] = {}

    def load_shard(i):
        if i not in shard_cache:
            shard_cache[i] = np.load(
                os.path.join(d, f"shard_{i:05d}.npz"))
        return shard_cache[i]

    flat = {}
    for path, entries in manifest["index"].items():
        if len(entries) == 1 and entries[0][1] == -1:
            flat[path] = load_shard(entries[0][0])[path]
        else:
            parts = [load_shard(sid)[f"{path}@@{s}"]
                     for sid, s, _ in entries]
            flat[path] = np.concatenate(parts, axis=0)
    return _unflatten(flat), manifest
