"""Explicit collectives: gradient compression for the DP all-reduce.

GSPMD inserts data-parallel grad reductions automatically, but those are
always full-precision. This module provides the explicit path (used by
train/dp_trainer.py inside shard_map) where the all-reduce payload can be
compressed:

  "none"  : fp32/bf16 psum as-is
  "bf16"  : cast fp32 grads to bf16 before psum (2x bytes saved; psum in
            bf16 accumulates in bf16 on-wire — the standard trade)
  "int8"  : per-tensor symmetric int8 quantization + all_gather + local
            dequant-sum (4x payload reduction per hop; exact mean of the
            quantized values — no int overflow since the sum is in fp32)

The collective-bytes effect is measurable in the lowered HLO, which is how
benchmarks/collectives_bench.py scores it.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp


def _quantize_int8(x):
    amax = jnp.max(jnp.abs(x)) + 1e-12
    scale = amax / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _axis_size(a):
    # jax.lax.axis_size is newer JAX; psum(1, axis) is the portable spelling
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(a)
    return jax.lax.psum(1, a)


def psum_tree(tree, axes, *, compress: str = "none", mean: bool = True):
    """All-reduce a grad pytree over `axes` (inside shard_map)."""
    axes = tuple(axes)
    n = 1
    for a in axes:
        n *= _axis_size(a)

    def reduce_leaf(g):
        if compress == "bf16" and g.dtype == jnp.float32:
            r = jax.lax.psum(g.astype(jnp.bfloat16), axes).astype(jnp.float32)
        elif compress == "int8":
            q, scale = _quantize_int8(g.astype(jnp.float32))
            qs = jax.lax.all_gather(q, axes, tiled=False)     # (n, ...)
            ss = jax.lax.all_gather(scale, axes, tiled=False)  # (n,)
            shape = (-1,) + (1,) * g.ndim
            r = jnp.sum(qs.reshape((qs.shape[0],) + g.shape).astype(jnp.float32)
                        * ss.reshape(shape), axis=0)
        else:
            r = jax.lax.psum(g, axes)
        return r / n if mean else r

    return jax.tree_util.tree_map(reduce_leaf, tree)
