"""Elastic scaling: rebuild meshes from survivors, reshard state.

Failure model: a job starts on H hosts; some die or new ones arrive (the
paper's machine-resize scenario, Fig. 5C, applied to the compute side).
Recovery = pick the largest valid mesh from the survivor count, reshard
the checkpointed state onto it, re-split data-pipeline file shards, and
let each host's InTune controller re-tune its ingestion pipeline for the
new CPU pool (that last part is automatic — it's the paper's entire point).
"""
from __future__ import annotations

from typing import Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def viable_mesh_shape(n_devices: int, *, model_parallel: int = 16,
                      min_model: int = 1) -> Tuple[int, ...]:
    """Largest (data, model) grid using <= n_devices devices.

    Keeps the model axis as large as the parallelism plan allows (TP degree
    is a property of the param shapes), shrinking it only when too few
    devices survive; the data axis absorbs the rest (power of two).
    """
    tp = model_parallel
    while tp > min_model and tp > n_devices:
        tp //= 2
    dp = max(1, 2 ** int(np.log2(max(n_devices // tp, 1))))
    return (dp, tp)


def make_mesh_from_devices(devices: Sequence, shape: Tuple[int, int],
                           axis_names=("data", "model")) -> Mesh:
    dp, tp = shape
    dev = np.asarray(devices[: dp * tp]).reshape(dp, tp)
    return Mesh(dev, axis_names)


def reshard(tree, specs_tree, new_mesh: Mesh):
    """Move a (host-local numpy or jax) pytree onto new_mesh shardings."""
    def place(x, spec):
        return jax.device_put(x, NamedSharding(new_mesh, spec))
    return jax.tree_util.tree_map(
        place, tree, specs_tree,
        is_leaf=lambda x: not isinstance(x, (dict, list, tuple)))


def split_file_shards(files: Sequence[str], n_hosts: int,
                      host_id: int) -> list:
    """Deterministic re-split of dataset files over surviving hosts."""
    return [f for i, f in enumerate(sorted(files)) if i % n_hosts == host_id]


class ElasticCoordinator:
    """Tracks resize events and produces recovery plans.

    In a real deployment the resize signal comes from the cluster scheduler;
    here it is injected by tests/benchmarks (the paper injects it manually
    too: 32 -> 64 -> 128 -> 64 -> 32 CPUs).
    """

    def __init__(self, n_devices: int, model_parallel: int = 16):
        self.model_parallel = model_parallel
        self.history: list[Tuple[int, Tuple[int, int]]] = []
        self.resize(n_devices)

    def resize(self, n_devices: int) -> Tuple[int, int]:
        shape = viable_mesh_shape(
            n_devices, model_parallel=self.model_parallel)
        self.current = shape
        self.history.append((n_devices, shape))
        return shape

    def recovery_plan(self, n_survivors: int) -> dict:
        shape = self.resize(n_survivors)
        return {
            "mesh_shape": shape,
            "devices_used": shape[0] * shape[1],
            "devices_idle": n_survivors - shape[0] * shape[1],
            "action": "restore latest checkpoint; reshard params/opt state;"
                      " re-split data files; InTune re-tunes pipelines",
        }
