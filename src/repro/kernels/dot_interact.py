"""Pallas TPU DLRM dot-interaction: batch-tiled pairwise feature dots.

The (B, F, D) feature block stays resident in VMEM; the F x F Gram matrix
is an MXU matmul per sample (batched dot_general); the lower-triangle
extraction is a second MXU matmul against a constant 0/1 selection matrix
(F^2, P) — a lane-gather would not lower cleanly on TPU, while the select
matmul stays in the systolic array and fuses with the Gram product. The
Gram tensor never round-trips HBM (the point of fusing — on GPU DLRM this
is HugeCTR's fused-interaction kernel, re-tiled here for VMEM/MXU).

Block shape: (TB, F, D) with TB sized so TB*F*D*2B stays well under VMEM
(default TB=128, F=27, D=128 -> 864 KiB bf16 + the 1 MiB select matrix).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


def _kernel(feats_ref, sel_ref, out_ref):
    f32 = jnp.float32
    x = feats_ref[...].astype(f32)                       # (TB, F, D)
    gram = jax.lax.dot_general(
        x, x, dimension_numbers=(((2,), (2,)), ((0,), (0,))),
        preferred_element_type=f32)                      # (TB, F, F)
    tb = x.shape[0]
    flat = gram.reshape(tb, -1)                          # (TB, F*F)
    out = jax.lax.dot(flat, sel_ref[...].astype(f32),
                      preferred_element_type=f32)        # (TB, P)
    out_ref[...] = out.astype(out_ref.dtype)


def select_matrix(f: int) -> np.ndarray:
    """(F*F, P) 0/1 matrix extracting lower-triangle (i > j) pairs."""
    ii, jj = np.tril_indices(f, k=-1)
    sel = np.zeros((f * f, len(ii)), np.float32)
    sel[ii * f + jj, np.arange(len(ii))] = 1.0
    return sel


def dot_interact(feats, *, tile_b: int = 128, interpret: bool = False):
    """feats: (B, F, D) -> (B, F*(F-1)/2), B % tile_b == 0."""
    b, f, d = feats.shape
    tile_b = min(tile_b, b)
    assert b % tile_b == 0, (b, tile_b)
    sel = jnp.asarray(select_matrix(f))
    n_pairs = sel.shape[1]
    return pl.pallas_call(
        _kernel,
        grid=(b // tile_b,),
        in_specs=[pl.BlockSpec((tile_b, f, d), lambda i: (i, 0, 0)),
                  pl.BlockSpec((f * f, n_pairs), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((tile_b, n_pairs), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, n_pairs), feats.dtype),
        interpret=interpret,
    )(feats, sel)
