"""Pallas TPU embedding-bag: fused multi-hot gather + reduce.

TPU adaptation (DESIGN.md §3): there is no native EmbeddingBag; the hot
loop is an HBM->VMEM row gather feeding the VPU. The scalar-prefetch trick
makes the id tensor available to the BlockSpec index_map, so each grid
step's *block index into the table* IS the looked-up row — the gather
happens in the pipelining layer (row DMA per step), and the kernel body is
a pure VMEM accumulate. Grid (B, bag) revisits each output row `bag` times
(TPU grids are sequential, so cross-step accumulation into the same output
block is the standard reduction pattern).

Perf note recorded for §Perf: (1, D) row blocks under-fill the 8-sublane
VREG tile; a production variant batches 8 ids per DMA. This kernel is the
faithful baseline.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
except ImportError:  # pragma: no cover
    pltpu = None


def _kernel(ids_ref, row_ref, out_ref, *, bag: int, combiner: str):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += row_ref[...].astype(out_ref.dtype)

    if combiner == "mean":
        @pl.when(j == bag - 1)
        def _final():
            out_ref[...] = out_ref[...] / bag


def embedding_bag(table, ids, *, combiner: str = "sum",
                  interpret: bool = False):
    """table: (V, D) f32/bf16; ids: (B, bag) int32 -> (B, D) f32.

    Accumulates in f32 (sum of bf16 rows loses mass for large bags).
    """
    b, bag = ids.shape
    v, d = table.shape
    kernel = functools.partial(_kernel, bag=bag, combiner=combiner)
    grid = (b, bag)

    def table_index(b_i, j, ids_ref):
        return (ids_ref[b_i, j], 0)

    def out_index(b_i, j, ids_ref):
        return (b_i, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[pl.BlockSpec((1, d), table_index)],
        out_specs=pl.BlockSpec((1, d), out_index),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, d), jnp.float32),
        interpret=interpret,
    )(ids, table)
