"""Pallas TPU embedding-bag: fused multi-hot gather + reduce.

TPU adaptation (DESIGN.md §3): there is no native EmbeddingBag; the hot
loop is an HBM->VMEM row gather feeding the VPU. The scalar-prefetch trick
makes the id tensor available to the BlockSpec index_map, so each grid
step's *block index into the table* IS the looked-up row — the gather
happens in the pipelining layer (row DMA per step), and the kernel body is
a pure VMEM accumulate. Grid (B, bag) revisits each output row `bag` times
(TPU grids are sequential, so cross-step accumulation into the same output
block is the standard reduction pattern).

Perf note recorded for §Perf: (1, D) row blocks under-fill the 8-sublane
VREG tile; a production variant batches 8 ids per DMA. `embedding_bag` is
the faithful baseline; `embedding_bag_fused` is the landed perf variant —
grid (B,) with the bag unrolled into `bag` scalar-prefetch row specs, so
one grid step sums the whole bag: bag x fewer grid steps (and kernel
dispatches in interpret mode), the output block is written once instead
of revisited bag times (no zero-init + read-modify-write round trips),
and the pipelining layer sees all bag row DMAs of a step at once instead
of one per step. Accumulation order over j is identical to the baseline,
so results match bit-for-bit (guarded by tests/test_kernels.py parity).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
except ImportError:  # pragma: no cover
    pltpu = None


def _kernel(ids_ref, row_ref, out_ref, *, bag: int, combiner: str):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += row_ref[...].astype(out_ref.dtype)

    if combiner == "mean":
        @pl.when(j == bag - 1)
        def _final():
            out_ref[...] = out_ref[...] / bag


def embedding_bag(table, ids, *, combiner: str = "sum",
                  interpret: bool = False):
    """table: (V, D) f32/bf16; ids: (B, bag) int32 -> (B, D) f32.

    Accumulates in f32 (sum of bf16 rows loses mass for large bags).
    """
    b, bag = ids.shape
    v, d = table.shape
    kernel = functools.partial(_kernel, bag=bag, combiner=combiner)
    grid = (b, bag)

    def table_index(b_i, j, ids_ref):
        return (ids_ref[b_i, j], 0)

    def out_index(b_i, j, ids_ref):
        return (b_i, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[pl.BlockSpec((1, d), table_index)],
        out_specs=pl.BlockSpec((1, d), out_index),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, d), jnp.float32),
        interpret=interpret,
    )(ids, table)


# the fused variant keeps the WHOLE table resident as one block, so it
# only fires when the table fits comfortably in VMEM (TPU budget ~16MB;
# stay at half to leave room for the output + ids)
_FUSED_MAX_TABLE_BYTES = 8 * 1024 * 1024
# unroll bound for the in-kernel bag loop
_FUSED_MAX_BAG = 16


def _fused_kernel(ids_ref, table_ref, out_ref, *, bag: int, combiner: str):
    """One grid step = one output row: gather + sum the whole bag.

    Same j-ascending, f32 accumulation order as the baseline's grid
    revisits — the two variants are bit-identical, not just close."""
    b_i = pl.program_id(0)

    def row(j):
        return pl.load(table_ref,
                       (pl.dslice(ids_ref[b_i, j], 1), slice(None)))

    acc = row(0).astype(out_ref.dtype)
    for j in range(1, bag):
        acc = acc + row(j).astype(out_ref.dtype)
    if combiner == "mean":
        acc = acc / bag
    out_ref[...] = acc


def embedding_bag_fused(table, ids, *, combiner: str = "sum",
                        interpret: bool = False):
    """Fused-bag variant of `embedding_bag` for VMEM-resident tables.

    Grid (B,) instead of (B, bag): the table is bound ONCE as a full
    (V, D) block (constant index map — the pipelining layer keeps it
    resident instead of re-issuing a row DMA every step), and each grid
    step gathers + reduces its whole bag in-kernel via scalar-prefetched
    ids. bag x fewer grid steps, and the output row is written once
    instead of zero-init + bag read-modify-write revisits. Falls back to
    the row-DMA baseline when the table exceeds the VMEM budget or the
    bag exceeds the unroll bound."""
    b, bag = ids.shape
    v, d = table.shape
    if (v * d * table.dtype.itemsize > _FUSED_MAX_TABLE_BYTES
            or bag > _FUSED_MAX_BAG):
        return embedding_bag(table, ids, combiner=combiner,
                             interpret=interpret)
    kernel = functools.partial(_fused_kernel, bag=bag, combiner=combiner)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b,),
        in_specs=[pl.BlockSpec((v, d), lambda b_i, ids_ref: (0, 0))],
        out_specs=pl.BlockSpec((1, d), lambda b_i, ids_ref: (b_i, 0)),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, d), jnp.float32),
        interpret=interpret,
    )(ids, table)
