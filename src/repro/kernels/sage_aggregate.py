"""Pallas TPU GraphSAGE block aggregation: fused mean-reduce + projection.

The minibatch GNN hot op: (B, F, D) dense-fanout neighbor features ->
mean over F -> @ W (D, H). Fusing the reduction with the projection keeps
the (TB, D) aggregate in VREGs and feeds the MXU directly; unfused, the
aggregate round-trips HBM. Weights are grid-invariant (one VMEM-resident
block reused across batch tiles).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(neigh_ref, w_ref, out_ref):
    f32 = jnp.float32
    x = neigh_ref[...].astype(f32)                  # (TB, F, D)
    agg = jnp.mean(x, axis=1)                       # (TB, D)
    out_ref[...] = jax.lax.dot(
        agg, w_ref[...].astype(f32),
        preferred_element_type=f32).astype(out_ref.dtype)


def sage_aggregate(neigh, w, *, tile_b: int = 128, interpret: bool = False):
    """neigh: (B, F, D); w: (D, H) -> (B, H), B % tile_b == 0."""
    b, f, d = neigh.shape
    h = w.shape[1]
    tile_b = min(tile_b, b)
    assert b % tile_b == 0, (b, tile_b)
    return pl.pallas_call(
        _kernel,
        grid=(b // tile_b,),
        in_specs=[pl.BlockSpec((tile_b, f, d), lambda i: (i, 0, 0)),
                  pl.BlockSpec((d, h), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((tile_b, h), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h), neigh.dtype),
        interpret=interpret,
    )(neigh, w)
