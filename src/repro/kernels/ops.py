"""jit'd public wrappers for the Pallas kernels.

`interpret=None` auto-selects: compiled on TPU, interpret-mode on CPU
(the kernel body executes in Python via the Pallas interpreter — this is
how correctness is validated in this container, per the assignment).
"""
from __future__ import annotations

import functools

import jax

from repro.kernels import dot_interact as _di
from repro.kernels import embedding_bag as _eb
from repro.kernels import sage_aggregate as _sa


def _auto_interpret(interpret):
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("combiner", "interpret"))
def embedding_bag(table, ids, *, combiner: str = "sum", interpret=None):
    return _eb.embedding_bag(table, ids, combiner=combiner,
                             interpret=_auto_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("combiner", "interpret"))
def embedding_bag_fused(table, ids, *, combiner: str = "sum",
                        interpret=None):
    """Perf variant: whole-bag reduction per grid step (bag x fewer grid
    steps than `embedding_bag`, bit-identical results)."""
    return _eb.embedding_bag_fused(table, ids, combiner=combiner,
                                   interpret=_auto_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("tile_b", "interpret"))
def dot_interact(feats, *, tile_b: int = 128, interpret=None):
    return _di.dot_interact(feats, tile_b=tile_b,
                            interpret=_auto_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("tile_b", "interpret"))
def sage_aggregate(neigh, w, *, tile_b: int = 128, interpret=None):
    return _sa.sage_aggregate(neigh, w, tile_b=tile_b,
                              interpret=_auto_interpret(interpret))
