"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def embedding_bag_ref(table, ids, *, combiner: str = "sum"):
    """table: (V, D); ids: (B, bag) -> (B, D)."""
    emb = jnp.take(table, ids, axis=0)          # (B, bag, D)
    out = jnp.sum(emb, axis=1)
    if combiner == "mean":
        out = out / ids.shape[1]
    return out


def dot_interact_ref(feats):
    """feats: (B, F, D) -> (B, F*(F-1)/2) lower-triangle pairwise dots."""
    f = feats.shape[1]
    gram = jnp.einsum("bfd,bgd->bfg", feats, feats,
                      preferred_element_type=jnp.float32)
    ii, jj = jnp.tril_indices(f, k=-1)
    return gram[:, ii, jj].astype(feats.dtype)


def sage_aggregate_ref(neigh, w):
    """neigh: (B, F, D); w: (D, H) -> mean over F then project: (B, H)."""
    agg = jnp.mean(neigh.astype(jnp.float32), axis=1)
    return (agg @ w.astype(jnp.float32)).astype(neigh.dtype)
