"""Program builder: (arch × shape × mesh) -> a lowered-compilable step.

This is the single place that knows how to assemble, for every assigned
architecture and input shape:
  - abstract parameters + optimizer state (jax.eval_shape, no allocation),
  - input ShapeDtypeStructs (`input_specs`, as required by the assignment),
  - in/out NamedShardings derived from logical axis rules (shardlib),
  - the step function itself (train_step / prefill / decode / serve /
    retrieval).

Both launch/dryrun.py (lower+compile on the production meshes) and
launch/train.py (real execution on the host mesh) consume Programs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.common import shardlib
from repro.configs.base import ArchSpec
from repro.models import dlrm as dlrm_lib
from repro.models import gnn as gnn_lib
from repro.models import recsys as recsys_lib
from repro.models import transformer as tfm
from repro.train.optim import make_optimizer, opt_logical_axes
from repro.train.train_step import make_train_step

# Logical dims that are "data-like": sharding them when the dim is smaller
# than the mesh axis would pad (e.g. batch=1 over 32 devices) — drop instead.
_DATA_DIMS = {"batch", "cache_batch", "candidates", "edges"}

f32, bf16, i32 = jnp.float32, jnp.bfloat16, jnp.int32


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


@dataclasses.dataclass
class Program:
    name: str                 # "<arch>/<shape>"
    kind: str                 # train | prefill | decode | serve | retrieval
    fn: Callable              # step function
    abstract_args: tuple      # ShapeDtypeStruct pytrees, one per fn arg
    in_shardings: tuple       # NamedSharding pytrees (or None), same arity
    out_shardings: Any
    donate_argnums: Tuple[int, ...] = ()
    meta: dict = dataclasses.field(default_factory=dict)


# ----------------------------------------------------------------- utils ---
def abstract_init(init_thunk):
    """eval_shape a params initializer returning (params, logical).

    `logical` is static python data built during tracing; captured via a
    side channel because eval_shape outputs must be arrays.
    """
    side = {}

    def wrapper():
        p, lg = init_thunk()
        side["lg"] = lg
        return p

    abs_p = jax.eval_shape(wrapper)
    return abs_p, side["lg"]


def _axes_prod(axis, mesh: Mesh) -> int:
    if axis is None:
        return 1
    axes = (axis,) if isinstance(axis, str) else axis
    n = 1
    for a in axes:
        n *= mesh.shape.get(a, 1)
    return n


def shardings_for(abstract_tree, logical_tree, rules, mesh: Mesh):
    """NamedSharding tree for an abstract pytree (divisible-or-replicate)."""
    flat_abs, treedef = jax.tree_util.tree_flatten(abstract_tree)
    flat_lg = treedef.flatten_up_to(logical_tree)
    out = [NamedSharding(mesh, shardlib.sanitized_pspec(
        abs_leaf.shape, tuple(lg), rules, mesh))
        for abs_leaf, lg in zip(flat_abs, flat_lg)]
    return jax.tree_util.tree_unflatten(treedef, out)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())


# ------------------------------------------------------------------- LM ----
def _lm_rules(arch: ArchSpec, shape, mesh):
    overrides = dict(arch.model.sharding_overrides)
    if shape.kind == "decode" and shape.batch < 8:
        # batch unshardable (long_500k): shard the KV cache seq over the
        # data axes instead (DESIGN.md §5); head_dim already covers `model`
        # via the arch override when kv heads don't divide.
        overrides.setdefault("cache_seq", ("pod", "data"))
    return shardlib.make_rules(overrides)


def lm_input_specs(arch: ArchSpec, shape):
    cfg = arch.model
    if shape.kind == "train":
        args = {"tokens": sds((shape.batch, shape.seq_len), i32),
                "labels": sds((shape.batch, shape.seq_len), i32)}
        logical = {"tokens": ("batch", "seq"), "labels": ("batch", "seq")}
        return args, logical
    if shape.kind == "prefill":
        return ({"tokens": sds((shape.batch, shape.seq_len), i32)},
                {"tokens": ("batch", "seq")})
    # decode: cache + one token + position
    cache = {"k": sds((cfg.n_layers, shape.batch, shape.seq_len,
                       cfg.n_kv_heads, cfg.head_dim), bf16),
             "v": sds((cfg.n_layers, shape.batch, shape.seq_len,
                       cfg.n_kv_heads, cfg.head_dim), bf16)}
    cache_lg = tfm.cache_logical_axes()
    args = {"cache": cache, "tokens": sds((shape.batch,), i32),
            "pos": sds((), i32)}
    logical = {"cache": cache_lg, "tokens": ("batch",), "pos": ()}
    return args, logical


def build_lm_program(arch: ArchSpec, shape, mesh: Mesh) -> Program:
    cfg = arch.model
    rules = _lm_rules(arch, shape, mesh)
    abs_params, p_logical = abstract_init(
        lambda: tfm.init_params(jax.random.PRNGKey(0), cfg))
    p_shard = shardings_for(abs_params, p_logical, rules, mesh)
    args, args_logical = lm_input_specs(arch, shape)
    a_shard = shardings_for(args, args_logical, rules, mesh)
    name = f"{arch.arch_id}/{shape.name}"

    ctx = shardlib.ShardCtx(mesh, rules)
    if shape.kind == "train":
        opt = make_optimizer(arch.optimizer, lr=3e-4)
        abs_opt = jax.eval_shape(opt.init, abs_params)
        o_logical = opt_logical_axes(arch.optimizer, p_logical,
                                     params=abs_params)
        o_shard = shardings_for(abs_opt, o_logical, rules, mesh)
        loss = lambda p, b: tfm.loss_fn(p, cfg, b, ctx=ctx)
        step_fn = make_train_step(loss, opt)
        return Program(
            name=name, kind="train", fn=step_fn,
            abstract_args=(abs_params, abs_opt, sds((), i32), args),
            in_shardings=(p_shard, o_shard, replicated(mesh), a_shard),
            out_shardings=(p_shard, o_shard, None),
            donate_argnums=(0, 1),
            meta={"params_logical": p_logical, "rules": rules})

    if shape.kind == "prefill":
        def prefill_fn(params, batch):
            return tfm.prefill(params, cfg, batch["tokens"], ctx=ctx)
        return Program(
            name=name, kind="prefill", fn=prefill_fn,
            abstract_args=(abs_params, args),
            in_shardings=(p_shard, a_shard),
            out_shardings=None,
            meta={"params_logical": p_logical, "rules": rules})

    def decode_fn(params, cache, tokens, pos):
        return tfm.decode_step(params, cfg, cache, tokens, pos, ctx=ctx)
    return Program(
        name=name, kind="decode", fn=decode_fn,
        abstract_args=(abs_params, args["cache"], args["tokens"],
                       args["pos"]),
        in_shardings=(p_shard, a_shard["cache"], a_shard["tokens"],
                      replicated(mesh)),
        out_shardings=(None, a_shard["cache"]),
        donate_argnums=(1,),
        meta={"params_logical": p_logical, "rules": rules})


# ------------------------------------------------------------------ GNN ----
def padded_edges(n_edges: int, multiple: int = 512) -> int:
    """Edge counts pad up so the edge axis shards evenly over any mesh
    (pad edges carry dst == n_nodes, dropped by segment_sum)."""
    return -(-n_edges // multiple) * multiple


def gnn_input_specs(arch: ArchSpec, shape):
    if shape.kind == "full_graph":
        e = padded_edges(shape.n_edges)
        args = {"x": sds((shape.n_nodes, shape.d_feat), f32),
                "edge_src": sds((e,), i32),
                "edge_dst": sds((e,), i32),
                "labels": sds((shape.n_nodes,), i32)}
        logical = {"x": ("nodes", None), "edge_src": ("edges",),
                   "edge_dst": ("edges",), "labels": ("nodes",)}
        return args, logical
    if shape.kind == "minibatch":
        b, (f1, f2), d = shape.batch_nodes, shape.fanout, shape.d_feat
        args = {"x0": sds((b, d), f32), "neigh1": sds((b, f1, d), f32),
                "neigh2": sds((b, f1, f2, d), f32),
                "labels": sds((b,), i32)}
        logical = {"x0": ("batch", None), "neigh1": ("batch", None, None),
                   "neigh2": ("batch", None, None, None),
                   "labels": ("batch",)}
        return args, logical
    # batched small graphs
    g, n, e, d = shape.n_graphs, shape.n_nodes, shape.n_edges, shape.d_feat
    args = {"x": sds((g, n, d), f32), "edge_src": sds((g, e), i32),
            "edge_dst": sds((g, e), i32), "node_mask": sds((g, n), f32),
            "labels": sds((g,), i32)}
    logical = {"x": ("batch", None, None), "edge_src": ("batch", None),
               "edge_dst": ("batch", None), "node_mask": ("batch", None),
               "labels": ("batch",)}
    return args, logical


def gnn_partitioned_input_specs(cfg, shape, mesh: Mesh):
    """dst-partitioned full-graph layout (§Perf hillclimb 3)."""
    axes = tuple(a for a in ("pod", "data", "model") if a in mesh.axis_names)
    n_shards = 1
    for a in axes:
        n_shards *= mesh.shape[a]
    n_pad = -(-shape.n_nodes // 512) * 512       # divides both meshes
    e_loc = -(-int(shape.n_edges * cfg.partition_slack) // n_shards)
    e_loc = -(-e_loc // 8) * 8
    args = {"x": sds((n_pad, shape.d_feat), f32),
            "edge_src": sds((n_shards, e_loc), i32),
            "edge_dst": sds((n_shards, e_loc), i32),
            "labels": sds((n_pad,), i32)}
    row_axes = axes if len(axes) > 1 else axes[0]
    P_ = jax.sharding.PartitionSpec
    shardings = {
        "x": NamedSharding(mesh, P_(None, None)),
        "edge_src": NamedSharding(mesh, P_(row_axes, None)),
        "edge_dst": NamedSharding(mesh, P_(row_axes, None)),
        "labels": NamedSharding(mesh, P_(row_axes)),
    }
    return args, shardings


def build_gnn_program(arch: ArchSpec, shape, mesh: Mesh) -> Program:
    cfg = arch.model
    rules = shardlib.make_rules(dict(cfg.sharding_overrides))
    abs_params, p_logical = abstract_init(
        lambda: gnn_lib.init_params(jax.random.PRNGKey(0), cfg,
                                    d_feat=shape.d_feat))
    p_shard = shardings_for(abs_params, p_logical, rules, mesh)
    partitioned = cfg.partitioned and shape.kind == "full_graph"
    if partitioned:
        args, a_shard = gnn_partitioned_input_specs(cfg, shape, mesh)
    else:
        args, args_logical = gnn_input_specs(arch, shape)
        a_shard = shardings_for(args, args_logical, rules, mesh)

    if partitioned:
        loss = lambda p, c, b: gnn_lib.full_graph_partitioned_loss(
            p, c, b, mesh)
    else:
        loss = {"full_graph": gnn_lib.full_graph_loss,
                "minibatch": gnn_lib.minibatch_loss,
                "batched_small": gnn_lib.batched_graphs_loss}[shape.kind]
    opt = make_optimizer(arch.optimizer, lr=1e-3)
    abs_opt = jax.eval_shape(opt.init, abs_params)
    o_logical = opt_logical_axes(arch.optimizer, p_logical, params=abs_params)
    o_shard = shardings_for(abs_opt, o_logical, rules, mesh)
    step_fn = make_train_step(lambda p, b: loss(p, cfg, b), opt)
    return Program(
        name=f"{arch.arch_id}/{shape.name}", kind="train", fn=step_fn,
        abstract_args=(abs_params, abs_opt, sds((), i32), args),
        in_shardings=(p_shard, o_shard, replicated(mesh), a_shard),
        out_shardings=(p_shard, o_shard, None),
        donate_argnums=(0, 1),
        meta={"params_logical": p_logical, "rules": rules})


# --------------------------------------------------------------- recsys ----
def _recsys_batch_spec(cfg, batch: int, with_label: bool):
    name = cfg.name
    if name in ("wide-deep", "xdeepfm"):
        args = {"sparse_ids": sds((batch, cfg.n_sparse, cfg.multi_hot), i32),
                "dense": sds((batch, cfg.n_dense), f32)}
        logical = {"sparse_ids": ("batch", None, None),
                   "dense": ("batch", None)}
    elif name == "dien":
        args = {"hist_ids": sds((batch, cfg.seq_len), i32),
                "hist_mask": sds((batch, cfg.seq_len), f32),
                "target_id": sds((batch,), i32),
                "dense": sds((batch, cfg.n_dense), f32)}
        logical = {"hist_ids": ("batch", "seq"), "hist_mask": ("batch", "seq"),
                   "target_id": ("batch",), "dense": ("batch", None)}
    elif name == "bert4rec":
        args = {"item_seq": sds((batch, cfg.seq_len), i32)}
        logical = {"item_seq": ("batch", "seq")}
        if with_label:
            args["mask_pos"] = sds((batch, cfg.n_mask), i32)
            args["mask_labels"] = sds((batch, cfg.n_mask), i32)
            args["neg_ids"] = sds((batch, cfg.n_mask, cfg.n_negatives), i32)
            logical["mask_pos"] = ("batch", None)
            logical["mask_labels"] = ("batch", None)
            logical["neg_ids"] = ("batch", None, None)
        return args, logical
    else:
        raise ValueError(name)
    if with_label:
        args["label"] = sds((batch,), f32)
        logical["label"] = ("batch",)
    return args, logical


def recsys_input_specs(arch: ArchSpec, shape):
    cfg = arch.model
    if shape.kind == "train":
        return _recsys_batch_spec(cfg, shape.batch, with_label=True)
    if shape.kind == "serve":
        return _recsys_batch_spec(cfg, shape.batch, with_label=False)
    # retrieval: one user + candidate ids
    user, user_lg = _recsys_batch_spec(cfg, 1, with_label=False)
    args = {"user": user, "cand_ids": sds((shape.n_candidates,), i32)}
    logical = {"user": user_lg, "cand_ids": ("candidates",)}
    return args, logical


def build_recsys_program(arch: ArchSpec, shape, mesh: Mesh) -> Program:
    cfg = arch.model
    rules = shardlib.make_rules(dict(cfg.sharding_overrides))
    rec_ctx = shardlib.ShardCtx(mesh, rules)
    init = recsys_lib.INIT[cfg.name]
    abs_params, p_logical = abstract_init(
        lambda: init(jax.random.PRNGKey(0), cfg))
    p_shard = shardings_for(abs_params, p_logical, rules, mesh)
    args, args_logical = recsys_input_specs(arch, shape)
    a_shard = shardings_for(args, args_logical, rules, mesh)
    name = f"{arch.arch_id}/{shape.name}"

    if cfg.name == "bert4rec":
        loss_fn = lambda p, b: recsys_lib.bert4rec_loss(p, cfg, b,
                                                        ctx=rec_ctx)
        fwd = lambda p, b: recsys_lib.bert4rec_encode(p, cfg, b["item_seq"],
                                                      ctx=rec_ctx)
    else:
        fwd_model = recsys_lib.FORWARD[cfg.name]
        loss_fn = lambda p, b: recsys_lib.ctr_loss(p, cfg, b, fwd_model,
                                                   ctx=rec_ctx)
        if cfg.name in ("wide-deep", "xdeepfm"):
            fwd = lambda p, b: fwd_model(p, cfg, b, ctx=rec_ctx)
        else:
            fwd = lambda p, b: fwd_model(p, cfg, b)

    if shape.kind == "train":
        opt = make_optimizer(arch.optimizer, lr=1e-2)
        abs_opt = jax.eval_shape(opt.init, abs_params)
        o_logical = opt_logical_axes(arch.optimizer, p_logical,
                                     params=abs_params)
        o_shard = shardings_for(abs_opt, o_logical, rules, mesh)
        step_fn = make_train_step(loss_fn, opt)
        return Program(
            name=name, kind="train", fn=step_fn,
            abstract_args=(abs_params, abs_opt, sds((), i32), args),
            in_shardings=(p_shard, o_shard, replicated(mesh), a_shard),
            out_shardings=(p_shard, o_shard, None),
            donate_argnums=(0, 1),
            meta={"params_logical": p_logical, "rules": rules})

    if shape.kind == "serve":
        return Program(
            name=name, kind="serve", fn=fwd,
            abstract_args=(abs_params, args),
            in_shardings=(p_shard, a_shard), out_shardings=None,
            meta={"params_logical": p_logical, "rules": rules})

    ctx = shardlib.ShardCtx(mesh, rules)

    def retrieval_fn(params, user, cand_ids):
        # 25 slabs of 40k (divisible by the 32-way dp axis) bound memory
        return recsys_lib.score_candidates(params, cfg, user, cand_ids,
                                           chunks=25, ctx=ctx)
    return Program(
        name=name, kind="retrieval", fn=retrieval_fn,
        abstract_args=(abs_params, args["user"], args["cand_ids"]),
        in_shardings=(p_shard, a_shard["user"], a_shard["cand_ids"]),
        out_shardings=None,
        meta={"params_logical": p_logical, "rules": rules})


# ----------------------------------------------------------------- DLRM ----
def dlrm_input_specs(arch: ArchSpec, shape):
    cfg = arch.model
    def batch_spec(batch, with_label):
        args = {"sparse_ids": sds((batch, cfg.n_sparse, cfg.multi_hot), i32),
                "dense": sds((batch, cfg.n_dense), f32)}
        logical = {"sparse_ids": ("batch", None, None),
                   "dense": ("batch", None)}
        if with_label:
            args["label"] = sds((batch,), f32)
            logical["label"] = ("batch",)
        return args, logical
    if shape.kind == "train":
        return batch_spec(shape.batch, True)
    if shape.kind == "serve":
        return batch_spec(shape.batch, False)
    user, user_lg = batch_spec(1, False)
    return ({"user": user, "cand_ids": sds((shape.n_candidates,), i32)},
            {"user": user_lg, "cand_ids": ("candidates",)})


def build_dlrm_program(arch: ArchSpec, shape, mesh: Mesh) -> Program:
    cfg = arch.model
    rules = shardlib.make_rules(dict(cfg.sharding_overrides))
    dlrm_ctx = shardlib.ShardCtx(mesh, rules)
    abs_params, p_logical = abstract_init(
        lambda: dlrm_lib.init_params(jax.random.PRNGKey(0), cfg))
    p_shard = shardings_for(abs_params, p_logical, rules, mesh)
    args, args_logical = dlrm_input_specs(arch, shape)
    a_shard = shardings_for(args, args_logical, rules, mesh)
    name = f"{arch.arch_id}/{shape.name}"

    if shape.kind == "train":
        opt = make_optimizer(arch.optimizer, lr=1e-2)
        abs_opt = jax.eval_shape(opt.init, abs_params)
        o_logical = opt_logical_axes(arch.optimizer, p_logical,
                                     params=abs_params)
        o_shard = shardings_for(abs_opt, o_logical, rules, mesh)
        step_fn = make_train_step(
            lambda p, b: dlrm_lib.loss_fn(p, cfg, b, ctx=dlrm_ctx), opt)
        return Program(
            name=name, kind="train", fn=step_fn,
            abstract_args=(abs_params, abs_opt, sds((), i32), args),
            in_shardings=(p_shard, o_shard, replicated(mesh), a_shard),
            out_shardings=(p_shard, o_shard, None),
            donate_argnums=(0, 1),
            meta={"params_logical": p_logical, "rules": rules})
    if shape.kind == "serve":
        fwd = lambda p, b: dlrm_lib.forward(p, cfg, b, ctx=dlrm_ctx)
        return Program(
            name=name, kind="serve", fn=fwd,
            abstract_args=(abs_params, args),
            in_shardings=(p_shard, a_shard), out_shardings=None,
            meta={"params_logical": p_logical, "rules": rules})

    def retrieval_fn(params, user, cand_ids):
        # user-side embeddings computed once; 40k candidate slabs
        return dlrm_lib.score_candidates(params, cfg, user, cand_ids,
                                         chunks=25, ctx=dlrm_ctx)
    return Program(
        name=name, kind="retrieval", fn=retrieval_fn,
        abstract_args=(abs_params, args["user"], args["cand_ids"]),
        in_shardings=(p_shard, a_shard["user"], a_shard["cand_ids"]),
        out_shardings=None,
        meta={"params_logical": p_logical, "rules": rules})


# -------------------------------------------------------------- dispatch ---
BUILDERS = {"lm": build_lm_program, "gnn": build_gnn_program,
            "recsys": build_recsys_program, "dlrm": build_dlrm_program}


def build_program(arch: ArchSpec, shape, mesh: Mesh) -> Program:
    return BUILDERS[arch.family](arch, shape, mesh)


def input_specs(arch: ArchSpec, shape_name: str):
    """Assignment-required API: ShapeDtypeStruct stand-ins for every input."""
    shape = arch.shape(shape_name)
    fn = {"lm": lm_input_specs, "gnn": gnn_input_specs,
          "recsys": recsys_input_specs, "dlrm": dlrm_input_specs}[arch.family]
    return fn(arch, shape)[0]
