"""Generic training driver: --arch <id> on the host mesh (CPU-runnable).

    PYTHONPATH=src python -m repro.launch.train --arch wide-deep \
        --steps 50 [--reduced] [--ckpt-dir DIR] [--batch N]

Runs REAL training steps with synthetic data for any registered arch:
  - `--reduced` (default on) swaps in a CPU-sized config of the same
    family so the driver finishes in seconds; `--full` uses the assigned
    production config (only sensible on real hardware).
  - checkpoints every --ckpt-every steps (atomic, resumable),
  - an InTune controller tunes the (simulated-machine) ingestion pipeline
    alongside, exactly as a per-host controller would in production.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, list_archs
from repro.core.controller import InTune
from repro.data.pipeline import criteo_pipeline
from repro.data.sampler import CSRGraph, NeighborSampler
from repro.data.simulator import MachineSpec
from repro.data.synthetic import (CriteoStream, TokenStream, bert4rec_batch,
                                  dien_batch)
from repro.models import dlrm as dlrm_lib
from repro.models import gnn as gnn_lib
from repro.models import recsys as recsys_lib
from repro.models import transformer as tfm
from repro.train import checkpoint as ckpt
from repro.train.optim import make_optimizer
from repro.train.train_step import make_train_step


# ------------------------------------------------------- reduced configs ---
def reduced_model(arch):
    m = arch.model
    if arch.family == "lm":
        # n_kv_heads must divide the reduced 4-head count
        kw = dict(n_layers=2, d_model=64, d_ff=128, vocab_size=512,
                  n_heads=4, n_kv_heads=2 if m.n_kv_heads > 1 else 1,
                  head_dim=16, attn_chunk=32, param_dtype="float32")
        if m.is_moe:
            kw.update(n_experts=8, n_shared_experts=min(m.n_shared_experts, 2),
                      top_k=min(m.top_k, 2), d_expert=48)
        if m.local_global_alternating:
            kw.update(sliding_window=16, scan_block=2)
        return m.replace(**kw)
    if arch.family == "gnn":
        return m.replace(d_hidden=16)
    if arch.family == "recsys":
        kw = dict(vocab_sizes=(512,) * max(len(m.vocab_sizes), 1))
        if m.name == "bert4rec":
            kw.update(n_items=512, seq_len=16, n_mask=3, n_negatives=7,
                      embed_dim=16)
        if m.name == "dien":
            kw.update(seq_len=16, embed_dim=8, gru_dim=16,
                      mlp_dims=(32, 16))
        if m.name in ("wide-deep", "xdeepfm"):
            kw.update(n_sparse=min(m.n_sparse, 8), embed_dim=8,
                      mlp_dims=(64, 32),
                      vocab_sizes=(512,) * min(m.n_sparse, 8))
            if m.cin_dims:
                kw.update(cin_dims=(12, 12))
        return m.replace(**kw)
    return m.replace(n_sparse=8, embed_dim=16, vocab_sizes=(512,) * 8,
                     bottom_mlp=(32, 16), top_mlp=(64, 32, 1))


# ------------------------------------------------------- batch factories ---
def make_batch_fn(arch, cfg, batch: int, rng: np.random.RandomState):
    fam = arch.family
    if fam == "lm":
        stream = TokenStream(cfg.vocab_size, 64)
        return lambda: {k: jnp.asarray(v)
                        for k, v in stream.batch(batch).items()}
    if fam == "gnn":
        g = CSRGraph.random(512, 4096, seed=0)
        x = rng.randn(512, 32).astype(np.float32)
        y = rng.randint(0, cfg.n_classes, 512)
        sampler = NeighborSampler(g, x, y, fanout=(5, 3))
        return lambda: {k: jnp.asarray(v)
                        for k, v in sampler.sample(batch).items()}
    if fam == "dlrm" or cfg.name in ("wide-deep", "xdeepfm"):
        n_sparse = cfg.n_sparse
        stream = CriteoStream(n_sparse=n_sparse, n_dense=cfg.n_dense,
                              vocab=cfg.vocab_sizes[0],
                              multi_hot=getattr(cfg, "multi_hot", 1))
        return lambda: {k: jnp.asarray(v) for k, v in
                        stream.feature_udf(stream.raw_block(batch)).items()}
    if cfg.name == "dien":
        return lambda: {k: jnp.asarray(v) for k, v in dien_batch(
            rng, batch, cfg.seq_len, cfg.vocab_sizes[0],
            cfg.n_dense).items()}
    # bert4rec
    return lambda: {k: jnp.asarray(v) for k, v in bert4rec_batch(
        rng, batch, cfg.seq_len, cfg.n_items, cfg.n_mask,
        cfg.n_negatives).items()}


def make_loss_fn(arch, cfg):
    fam = arch.family
    if fam == "lm":
        return lambda p, b: tfm.loss_fn(p, cfg, b)
    if fam == "gnn":
        return lambda p, b: gnn_lib.minibatch_loss(p, cfg, b)
    if fam == "dlrm":
        return lambda p, b: dlrm_lib.loss_fn(p, cfg, b)
    if cfg.name == "bert4rec":
        return lambda p, b: recsys_lib.bert4rec_loss(p, cfg, b)
    fwd = recsys_lib.FORWARD[cfg.name]
    return lambda p, b: recsys_lib.ctr_loss(p, cfg, b, fwd)


def init_params_for(arch, cfg, rng_key):
    fam = arch.family
    if fam == "lm":
        return tfm.init_params(rng_key, cfg)[0]
    if fam == "gnn":
        return gnn_lib.init_params(rng_key, cfg, d_feat=32)[0]
    if fam == "dlrm":
        return dlrm_lib.init_params(rng_key, cfg)[0]
    return recsys_lib.INIT[cfg.name](rng_key, cfg)[0]


# ---------------------------------------------------------------- driver ---
def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--full", action="store_true",
                    help="use the production config (real hardware only)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--lr", type=float, default=1e-3)
    args = ap.parse_args(argv)

    arch = get_arch(args.arch)
    cfg = arch.model if args.full else reduced_model(arch)
    rng = np.random.RandomState(0)
    params = init_params_for(arch, cfg, jax.random.PRNGKey(0))
    n_params = sum(int(np.prod(p.shape))
                   for p in jax.tree_util.tree_leaves(params))
    print(f"arch={args.arch} family={arch.family} "
          f"params={n_params/1e6:.2f}M optimizer={arch.optimizer}")

    opt = make_optimizer(arch.optimizer, lr=args.lr)
    opt_state = opt.init(params)
    step_fn = jax.jit(make_train_step(make_loss_fn(arch, cfg), opt))
    batch_fn = make_batch_fn(arch, cfg, args.batch, rng)

    tuner = InTune(criteo_pipeline(), MachineSpec(n_cpus=128), seed=0,
                   head="factored", finetune_ticks=100)
    start = 0
    if args.ckpt_dir and ckpt.latest_step(args.ckpt_dir) is not None:
        tree, manifest = ckpt.restore(args.ckpt_dir)
        params, opt_state = tree["params"], tree["opt_state"]
        start = manifest["step"] + 1
        print(f"resumed from step {start - 1}")

    t0 = time.time()
    losses = []
    for i in range(start, args.steps):
        params, opt_state, metrics = step_fn(params, opt_state, i,
                                             batch_fn())
        tuner.tick()
        losses.append(float(metrics["loss"]))
        if i % 10 == 0:
            print(f"step {i:4d} loss {losses[-1]:.4f} "
                  f"pipeline {tuner.history[-1]['throughput']:.1f} b/s")
        if args.ckpt_dir and ((i + 1) % args.ckpt_every == 0
                              or i == args.steps - 1):
            ckpt.save(args.ckpt_dir, i,
                      {"params": params, "opt_state": opt_state})
    dt = time.time() - t0
    print(f"done: {len(losses)} steps in {dt:.1f}s; "
          f"loss {losses[0]:.4f} -> {np.mean(losses[-5:]):.4f}")


if __name__ == "__main__":
    main()
