"""Loop-aware HLO analysis: FLOPs and collective bytes that COUNT loop trips.

XLA's compiled.cost_analysis() counts each while-loop body once, so a
61-layer scanned transformer reports ~1/61st of its real per-step work
(verified empirically: smollm train_4k shows ~4x-low FLOPs). This module
re-derives the two roofline inputs from the SPMD module text:

  - dot_flops: 2 * |out| * |contraction| for every dot op, each multiplied
    by the product of trip counts of its enclosing while loops (matmul
    flops dominate every assigned arch; elementwise flops are the
    cost_analysis residual),
  - collective bytes per op type, same loop scaling.

Computation nesting is resolved through `body=`/`condition=`/`to_apply=`/
`calls=` references; trip counts come from the loop-condition comparison
constant (jax scan loops compare an induction variable against a literal).
"""
from __future__ import annotations

import re
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
# header lines look like "%name (args...) -> type {" with possibly NESTED
# parens in the arg list — match only the name prefix, gate on "->" + "{".
_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(")


def shape_dims(shape_str: str) -> Tuple[str, List[int]]:
    m = _SHAPE_RE.match(shape_str)
    if not m:
        return "", []
    dt, dims = m.groups()
    return dt, [int(d) for d in dims.split(",") if d]


def shape_bytes(shape_str: str) -> int:
    dt, dims = shape_dims(shape_str)
    if dt not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims:
        n *= d
    return n * _DTYPE_BYTES[dt]


def split_computations(hlo: str) -> Dict[str, List[str]]:
    """computation name -> its op lines. Entry computation keyed 'ENTRY'."""
    comps: Dict[str, List[str]] = {}
    current = None
    for line in hlo.splitlines():
        stripped = line.strip()
        if not stripped:
            continue
        m = _COMP_HDR.match(stripped)
        if m and stripped.endswith("{") and " -> " in stripped \
                and " = " not in stripped:
            name = m.group(2)
            current = "ENTRY" if m.group(1) else name
            comps[current] = []
            if m.group(1):
                comps[name] = comps[current]   # alias real name
            continue
        if stripped == "}":
            current = None
            continue
        if current is not None:
            comps[current].append(stripped)
    return comps


def _cond_trip_count(cond_lines: List[str]) -> int:
    """Loop bound from the condition computation's compare-vs-constant."""
    consts = []
    for line in cond_lines:
        for m in re.finditer(r"constant\((\d+)\)", line):
            consts.append(int(m.group(1)))
    if not consts:
        return 1
    return max(consts)


_REF_RE = re.compile(
    r"(?:body|condition|to_apply|calls)=\{?%?([\w\.\-]+)")


def computation_factors(hlo: str) -> Tuple[Dict[str, List[str]],
                                           Dict[str, float]]:
    """Execution multiplicity per computation (product of enclosing trips)."""
    comps = split_computations(hlo)
    factors: Dict[str, float] = {}
    if "ENTRY" not in comps:
        # fall back: treat every computation as factor 1
        return comps, {k: 1.0 for k in comps}
    factors["ENTRY"] = 1.0
    work = ["ENTRY"]
    seen = set()
    while work:
        name = work.pop()
        if name in seen:
            continue
        seen.add(name)
        f = factors.get(name, 1.0)
        for line in comps.get(name, ()):
            is_while = re.search(r"\bwhile\(", line) is not None
            body = cond = None
            if is_while:
                mb = re.search(r"body=\{?%?([\w\.\-]+)", line)
                mc = re.search(r"condition=\{?%?([\w\.\-]+)", line)
                body = mb.group(1) if mb else None
                cond = mc.group(1) if mc else None
                trips = _cond_trip_count(comps.get(cond, [])) if cond else 1
                if body and body in comps:
                    factors[body] = max(factors.get(body, 0.0), f * trips)
                    work.append(body)
                if cond and cond in comps:
                    factors[cond] = max(factors.get(cond, 0.0), f * trips)
                    work.append(cond)
            for m in _REF_RE.finditer(line):
                ref = m.group(1)
                if ref in (body, cond):
                    continue
                if ref in comps:
                    factors[ref] = max(factors.get(ref, 0.0), f)
                    work.append(ref)
    return comps, factors


_DOT_RE = re.compile(
    r"=\s*([a-z0-9]+\[[\d,]*\])(?:\{[\d,]*\})?\s+dot\(")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
# operands print either as "f32[..] %name" (verbose) or "%name" (short)
_DOT_LHS_SHAPE = re.compile(r"dot\(\s*([a-z0-9]+\[[\d,]*\])")
_DOT_LHS_NAME = re.compile(r"dot\(\s*%?([\w\.\-]+)")
_DEF_RE = re.compile(r"^%?([\w\.\-]+)\s*=\s*([a-z0-9]+\[[\d,]*\])")


def analyze(hlo: str) -> dict:
    """Loop-aware dot FLOPs + collective bytes for one SPMD module."""
    comps, factors = computation_factors(hlo)
    dot_flops = 0.0
    colls = {k: {"count": 0.0, "bytes": 0.0} for k in COLLECTIVE_OPS}
    for name, lines in comps.items():
        if name == "ENTRY":
            continue  # aliased to its real name; avoid double counting
        f = factors.get(name, 1.0)
        # local symbol table: op name -> result shape (short-form operands)
        shapes = {}
        for line in lines:
            mdef = _DEF_RE.match(line)
            if mdef:
                shapes[mdef.group(1)] = mdef.group(2)
        for line in lines:
            md = _DOT_RE.search(line)
            if md:
                _, out_dims = shape_dims(md.group(1))
                mc = _CONTRACT_RE.search(line)
                lhs_dims = []
                ms = _DOT_LHS_SHAPE.search(line)
                if ms:
                    _, lhs_dims = shape_dims(ms.group(1))
                else:
                    mn = _DOT_LHS_NAME.search(line)
                    if mn and mn.group(1) in shapes:
                        _, lhs_dims = shape_dims(shapes[mn.group(1)])
                if mc is not None and lhs_dims:
                    cdims = [int(c) for c in mc.group(1).split(",") if c]
                    k = 1
                    for c in cdims:
                        if c < len(lhs_dims):
                            k *= lhs_dims[c]
                    n_out = 1
                    for d in out_dims:
                        n_out *= d
                    dot_flops += f * 2.0 * n_out * k
                continue
            for op in COLLECTIVE_OPS:
                if f" {op}(" in line or f" {op}-start(" in line:
                    lhs = line.split(" = ", 1)
                    if len(lhs) != 2:
                        break
                    shapes_part = lhs[1].split(op)[0].strip()
                    if shapes_part.startswith("("):
                        coll_shapes = re.findall(r"[a-z0-9]+\[[\d,]*\]",
                                                 shapes_part)
                    else:
                        coll_shapes = re.findall(r"^[a-z0-9]+\[[\d,]*\]",
                                                 shapes_part)
                    b = sum(shape_bytes(s) for s in coll_shapes)
                    colls[op]["count"] += f
                    colls[op]["bytes"] += f * b
                    break
    total = sum(v["bytes"] for v in colls.values())
    return {"dot_flops": dot_flops, "collectives": colls,
            "collective_bytes": total}
