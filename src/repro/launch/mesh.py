"""Production mesh construction.

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state (smoke tests must keep seeing 1 CPU device).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """(16,16)=(data,model) single pod; (2,16,16)=(pod,data,model) two pods."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """1-device mesh with the same axis names (CPU smoke tests)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def dp_size(mesh) -> int:
    n = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            n *= mesh.shape[a]
    return n


def tp_size(mesh) -> int:
    return mesh.shape.get("model", 1) if "model" in mesh.axis_names else 1
