import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))
# The two lines above MUST run before any other import (jax locks the device
# count on first init). Everything else follows.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this:
  1. builds the Program (abstract params/opt/inputs + shardings),
  2. jit(...).lower(...).compile() on the requested mesh,
  3. records memory_analysis (bytes/device — proves it fits),
     cost_analysis (FLOPs/bytes for §Roofline), and the collective
     schedule (op-type -> operand bytes, parsed from the SPMD module),
  4. writes experiments/dryrun/<mesh>/<arch>__<shape>.json.

Usage:
  python -m repro.launch.dryrun --arch smollm-135m --shape train_4k
  python -m repro.launch.dryrun --all [--mesh single|multi|both]
Skipped cells (per assignment rules) are recorded with their reason.
"""
import argparse
import json
import re
import sys
import time
import traceback

import jax

from repro.configs import get_arch, list_archs
from repro.launch import hlo_analysis
from repro.launch.mesh import make_production_mesh
from repro.launch.programs import build_program

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "u1": 1, "s1": 1, "s4": 1, "u4": 1, "f8e4m3fn": 1,
    "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """bytes of one HLO shape literal like 'bf16[4,1024]'."""
    m = _SHAPE_RE.match(shape_str)
    if not m:
        return 0
    dt, dims = m.groups()
    if dt not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dt]


def parse_collectives(hlo_text: str) -> dict:
    """Sum per-device operand bytes of every collective op in an SPMD module.

    Matches lines like:
      %ag = bf16[8,128]{1,0} all-gather(%x), ...
      %t = (f32[4], f32[4]) all-reduce(...), ...
    Output-side shapes are used (operand ~= output for these ops except
    all-gather where output is the gathered size — we take the op's result
    shape, the standard payload accounting for ring algorithms).
    """
    out = {k: {"count": 0, "bytes": 0} for k in COLLECTIVE_OPS}
    # strip sharding annotations to keep the regex simple
    for line in hlo_text.splitlines():
        line = line.strip()
        for op in COLLECTIVE_OPS:
            # match "= <shape-or-tuple> op-name(" — avoids -start/-done pairs
            # of async collectives being double counted (we count -start).
            marker_plain = f" {op}("
            marker_start = f" {op}-start("
            if marker_plain not in line and marker_start not in line:
                continue
            lhs = line.split(" = ", 1)
            if len(lhs) != 2:
                continue
            rhs = lhs[1]
            shapes_part = rhs.split(op)[0].strip()
            if shapes_part.startswith("("):
                shapes = re.findall(r"[a-z0-9]+\[[\d,]*\]", shapes_part)
            else:
                shapes = re.findall(r"^[a-z0-9]+\[[\d,]*\]", shapes_part)
            b = sum(_shape_bytes(s) for s in shapes)
            out[op]["count"] += 1
            out[op]["bytes"] += b
            break
    out["total_bytes"] = sum(
        v["bytes"] for k, v in out.items() if isinstance(v, dict))
    return out


def run_cell(arch_id: str, shape_name: str, mesh, mesh_tag: str,
             verbose: bool = True) -> dict:
    arch = get_arch(arch_id)
    reason = arch.is_skipped(shape_name)
    if reason:
        return {"arch": arch_id, "shape": shape_name, "mesh": mesh_tag,
                "status": "skipped", "reason": reason}
    shape = arch.shape(shape_name)
    t0 = time.time()
    prog = build_program(arch, shape, mesh)
    jitted = jax.jit(prog.fn, in_shardings=prog.in_shardings,
                     out_shardings=prog.out_shardings,
                     donate_argnums=prog.donate_argnums)
    with mesh:
        lowered = jitted.lower(*prog.abstract_args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    cost = dict(cost) if cost else {}
    hlo = compiled.as_text()
    coll = parse_collectives(hlo)          # naive (loop bodies once)
    loop_aware = hlo_analysis.analyze(hlo)  # trips-scaled (§Roofline input)
    rec = {
        "arch": arch_id, "shape": shape_name, "mesh": mesh_tag,
        "status": "ok", "kind": prog.kind,
        "n_devices": mesh.devices.size,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory_analysis": {
            k: int(getattr(mem, k, 0)) for k in
            ("argument_size_in_bytes", "output_size_in_bytes",
             "temp_size_in_bytes", "generated_code_size_in_bytes",
             "alias_size_in_bytes")
        },
        "cost_analysis": {k: float(v) for k, v in cost.items()
                          if isinstance(v, (int, float))},
        "collectives_naive": coll,
        "loop_aware": loop_aware,
        "hlo_bytes": len(hlo),
    }
    if verbose:
        m = rec["memory_analysis"]
        print(f"[{mesh_tag}] {arch_id}/{shape_name}: OK "
              f"lower {t_lower:.1f}s compile {t_compile:.1f}s | "
              f"args/dev {m['argument_size_in_bytes']/2**30:.2f} GiB "
              f"temp/dev {m['temp_size_in_bytes']/2**30:.2f} GiB | "
              f"dotflops/dev {loop_aware['dot_flops']:.3e} | "
              f"coll {loop_aware['collective_bytes']/2**30:.2f} GiB/dev")
    return rec


def save_record(rec: dict, out_dir: str):
    d = os.path.join(out_dir, rec["mesh"])
    os.makedirs(d, exist_ok=True)
    path = os.path.join(d, f"{rec['arch']}__{rec['shape']}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="arch id (default: all)")
    ap.add_argument("--shape", default=None, help="shape name (default: all)")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    out_dir = args.out or os.path.abspath(OUT_DIR)
    archs = [args.arch] if args.arch else list_archs()
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[
        args.mesh]

    failures = []
    for multi in meshes:
        mesh = make_production_mesh(multi_pod=multi)
        tag = "pod2x16x16" if multi else "pod16x16"
        for arch_id in archs:
            arch = get_arch(arch_id)
            shapes = [args.shape] if args.shape else \
                [s.name for s in arch.shapes]
            for shape_name in shapes:
                try:
                    rec = run_cell(arch_id, shape_name, mesh, tag)
                except Exception as e:  # noqa: BLE001 — record and continue
                    traceback.print_exc()
                    rec = {"arch": arch_id, "shape": shape_name, "mesh": tag,
                           "status": "error", "error": repr(e)}
                    failures.append(f"{tag}/{arch_id}/{shape_name}")
                save_record(rec, out_dir)
    if failures:
        print(f"\nFAILED cells ({len(failures)}):")
        for f in failures:
            print(" ", f)
        sys.exit(1)
    print("\nall requested cells OK")


if __name__ == "__main__":
    main()
