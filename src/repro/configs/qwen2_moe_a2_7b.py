"""qwen2-moe-a2.7b [hf:Qwen/Qwen1.5-MoE-A2.7B].

24L d_model=2048 16H (GQA kv=16 => MHA) d_ff=1408 vocab=151936,
MoE: 60 routed experts top-4 + 4 shared experts (shared ffn = 4*1408=5632).
Experts pad 60 -> 64 on the 16-way model axis (DESIGN.md §5).
"""
from repro.configs.base import ArchSpec, LM_SHAPES, TransformerConfig

MODEL = TransformerConfig(
    name="qwen2-moe-a2.7b",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=1408, vocab_size=151936,
    n_experts=60, n_shared_experts=4, top_k=4, d_expert=1408,
    qkv_bias=True, rope_theta=1_000_000.0, tie_embeddings=False,
    act="silu", remat="full",
)

ARCH = ArchSpec(
    arch_id="qwen2-moe-a2.7b", family="lm", model=MODEL, shapes=LM_SHAPES,
    source="hf:Qwen/Qwen1.5-MoE-A2.7B", optimizer="adam",
    skipped_shapes=(
        ("long_500k",
         "pure full-attention arch; long_500k runs only for "
         "sub-quadratic/hybrid attention per assignment"),
    ),
)
