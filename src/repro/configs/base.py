"""Config dataclasses: model architectures, input shapes, arch registry spec.

Frozen dataclasses so configs are hashable (usable as jit static args).
Sharding overrides are tuple-of-pairs for the same reason.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple


# ------------------------------------------------------------------ LM -----
@dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    # MoE (n_experts == 0 -> dense MLP)
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    d_expert: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    norm_topk_prob: bool = True
    # attention / block details
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    sliding_window: int = 0            # 0 = full attention
    local_global_alternating: bool = False  # gemma2: even layers local
    attn_logit_softcap: float = 0.0
    final_logit_softcap: float = 0.0
    post_norm: bool = False            # gemma2 post-block norms
    scale_embed: bool = False          # gemma multiplies embed by sqrt(d)
    act: str = "silu"
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    # execution
    attn_chunk: int = 512
    remat: str = "full"                # "none" | "full" | "dots"
    scan_layers: bool = True
    scan_block: int = 1                # layers per scan step (2 for gemma2)
    param_dtype: str = "bfloat16"
    moe_impl: str = "shardmap_ep"      # "shardmap_ep" | "dense"
    sharding_overrides: Tuple[Tuple[str, Optional[str]], ...] = ()

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def n_experts_padded(self, tp: int) -> int:
        """Experts padded up so the expert axis divides the TP degree."""
        if not self.is_moe:
            return 0
        return -(-self.n_experts // tp) * tp

    def replace(self, **kw):
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class LMShape:
    name: str
    kind: str        # "train" | "prefill" | "decode"
    seq_len: int
    batch: int


LM_SHAPES = (
    LMShape("train_4k", "train", 4096, 256),
    LMShape("prefill_32k", "prefill", 32768, 32),
    LMShape("decode_32k", "decode", 32768, 128),
    LMShape("long_500k", "decode", 524288, 1),
)


# ----------------------------------------------------------------- GNN -----
@dataclass(frozen=True)
class GNNConfig:
    name: str
    n_layers: int = 2
    d_hidden: int = 128
    n_classes: int = 47
    aggregator: str = "mean"
    sample_sizes: Tuple[int, ...] = (25, 10)
    param_dtype: str = "float32"
    # §Perf hillclimb knob: dst-partitioned edge shards with node-sharded
    # layer outputs (full-graph cells) instead of edge-sharding + psums of
    # node-sized partials
    partitioned: bool = False
    # per-shard edge padding headroom for dst-partition skew
    partition_slack: float = 1.25
    sharding_overrides: Tuple[Tuple[str, Optional[str]], ...] = ()

    def replace(self, **kw):
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class GNNShape:
    name: str
    kind: str                  # "full_graph" | "minibatch" | "batched_small"
    n_nodes: int = 0
    n_edges: int = 0
    d_feat: int = 0
    batch_nodes: int = 0
    fanout: Tuple[int, ...] = ()
    n_graphs: int = 0          # batched_small: graphs per batch


GNN_SHAPES = (
    GNNShape("full_graph_sm", "full_graph", n_nodes=2708, n_edges=10556,
             d_feat=1433),
    GNNShape("minibatch_lg", "minibatch", n_nodes=232965, n_edges=114615892,
             d_feat=602, batch_nodes=1024, fanout=(15, 10)),
    GNNShape("ogb_products", "full_graph", n_nodes=2449029, n_edges=61859140,
             d_feat=100),
    GNNShape("molecule", "batched_small", n_nodes=30, n_edges=64, d_feat=32,
             n_graphs=128),
)


# -------------------------------------------------------------- recsys -----
@dataclass(frozen=True)
class RecsysConfig:
    name: str
    interaction: str                    # "concat" | "cin" | "augru" | "bidir-seq" | "dot"
    n_sparse: int = 0
    embed_dim: int = 32
    mlp_dims: Tuple[int, ...] = ()
    n_dense: int = 13
    # per-table vocab sizes (hashed); len == n_sparse
    vocab_sizes: Tuple[int, ...] = ()
    multi_hot: int = 1                  # lookups per sparse feature (bag size)
    # xDeepFM
    cin_dims: Tuple[int, ...] = ()
    # DIEN / BERT4Rec sequence settings
    seq_len: int = 0
    gru_dim: int = 0
    n_blocks: int = 0
    n_heads: int = 0
    n_items: int = 0                    # item vocab for sequence models
    n_mask: int = 0                     # BERT4Rec: masked positions per seq
    n_negatives: int = 0                # BERT4Rec: sampled-softmax negatives
    # §Perf hillclimb knob: shard_map row-sharded lookups / sampled-logit
    # psum instead of GSPMD take() over the sharded item table
    tp_lookup: bool = False
    param_dtype: str = "float32"
    sharding_overrides: Tuple[Tuple[str, Optional[str]], ...] = ()

    def replace(self, **kw):
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class RecsysShape:
    name: str
    kind: str            # "train" | "serve" | "retrieval"
    batch: int
    n_candidates: int = 0


RECSYS_SHAPES = (
    RecsysShape("train_batch", "train", 65536),
    RecsysShape("serve_p99", "serve", 512),
    RecsysShape("serve_bulk", "serve", 262144),
    RecsysShape("retrieval_cand", "retrieval", 1, n_candidates=1_000_000),
)


# ------------------------------------------------------ DLRM (the paper) ---
@dataclass(frozen=True)
class DLRMConfig:
    name: str
    n_sparse: int = 26
    n_dense: int = 13
    embed_dim: int = 128
    vocab_sizes: Tuple[int, ...] = ()
    bottom_mlp: Tuple[int, ...] = (512, 256, 128)
    top_mlp: Tuple[int, ...] = (1024, 1024, 512, 256, 1)
    multi_hot: int = 1
    param_dtype: str = "float32"
    # §Perf hillclimb knob: shard_map row-sharded lookup (models/embedding
    # tp_multifeature_bag) instead of GSPMD take() over the sharded table
    tp_lookup: bool = False
    sharding_overrides: Tuple[Tuple[str, Optional[str]], ...] = ()

    def replace(self, **kw):
        return dataclasses.replace(self, **kw)


# ------------------------------------------------------------- registry ----
@dataclass(frozen=True)
class ArchSpec:
    """One assigned architecture: model config + its shape set + metadata."""
    arch_id: str
    family: str                     # "lm" | "gnn" | "recsys" | "dlrm"
    model: object                   # one of the configs above
    shapes: Tuple[object, ...]
    source: str = ""
    optimizer: str = "adam"
    # cells skipped per assignment rules, with the reason
    skipped_shapes: Tuple[Tuple[str, str], ...] = ()

    def shape(self, name: str):
        for s in self.shapes:
            if s.name == name:
                return s
        raise KeyError(f"{self.arch_id} has no shape {name!r} "
                       f"(have {[s.name for s in self.shapes]})")

    def is_skipped(self, shape_name: str) -> Optional[str]:
        for name, reason in self.skipped_shapes:
            if name == shape_name:
                return reason
        return None
