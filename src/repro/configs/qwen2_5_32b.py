"""qwen2.5-32b [hf:Qwen/Qwen2.5-32B family].

64L d_model=5120 40H (GQA kv=8, head_dim=128) d_ff=27648 vocab=152064,
QKV bias. NOTE: 40 q-heads / 8 kv-heads don't divide the 16-way model axis,
so attention tensor-parallelism goes over head_dim (128/16=8 per shard);
score/value contractions psum over `model` (see sharding_overrides).
"""
from repro.configs.base import ArchSpec, LM_SHAPES, TransformerConfig

MODEL = TransformerConfig(
    name="qwen2.5-32b",
    n_layers=64, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
    d_ff=27648, vocab_size=152064,
    qkv_bias=True, rope_theta=1_000_000.0, tie_embeddings=False,
    act="silu", remat="full", attn_chunk=256,
    # 40 q-heads / 8 kv-heads don't divide the 16-way model axis: attention
    # runs context-parallel (q seq dim over `model`); attention weights
    # store TP over head_dim (128/16); decode cache shards head_dim.
    sharding_overrides=(("head_dim", "model"), ("act_q_seq", "model"),
                        ("cache_head_dim", "model")),
)

ARCH = ArchSpec(
    arch_id="qwen2.5-32b", family="lm", model=MODEL, shapes=LM_SHAPES,
    source="hf:Qwen/Qwen2.5-0.5B (scaled per assignment)", optimizer="adam",
    skipped_shapes=(
        ("long_500k",
         "pure full-attention arch; long_500k runs only for "
         "sub-quadratic/hybrid attention per assignment"),
    ),
)
