from repro.configs.base import (  # noqa: F401
    ArchSpec, DLRMConfig, GNNConfig, GNNShape, LMShape, RecsysConfig,
    RecsysShape, TransformerConfig,
)
from repro.configs.registry import get_arch, list_archs  # noqa: F401
