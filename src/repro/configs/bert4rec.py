"""bert4rec [arXiv:1904.06690].

embed_dim=64, 2 transformer blocks, 2 heads, seq_len=200, bidirectional
self-attention, masked-item (cloze) objective. Item vocab 2^20 (>= the 1M
candidates of the retrieval_cand cell). Encoder-only: its shape set has no
decode cell, so nothing is skipped.
"""
from repro.configs.base import ArchSpec, RECSYS_SHAPES, RecsysConfig

MODEL = RecsysConfig(
    name="bert4rec", interaction="bidir-seq",
    embed_dim=64, n_blocks=2, n_heads=2, seq_len=200, n_items=1 << 20,
    vocab_sizes=(1 << 20,),
    # full softmax over 2^20 items is infeasible at batch 65536 (5.5e16 B of
    # logits) — cloze training uses sampled softmax: 20 masked positions,
    # 127 uniform negatives per position (index 0 = true item).
    n_mask=20, n_negatives=127,
    # §Perf-optimized default (EXPERIMENTS.md §Perf iter1): shard_map item
    # lookups + sampled-logit psum; 2.2x fewer collective bytes than GSPMD
    # take over the row-sharded table.
    tp_lookup=True,
)

ARCH = ArchSpec(
    arch_id="bert4rec", family="recsys", model=MODEL, shapes=RECSYS_SHAPES,
    source="arXiv:1904.06690", optimizer="adam",
)
