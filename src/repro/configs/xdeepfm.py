"""xdeepfm [arXiv:1803.05170].

39 sparse features (Criteo: 26 categorical + 13 bucketized dense),
embed_dim=10, CIN layers 200-200-200, DNN 400-400, linear arm.
Hashed vocab 2^20 rows per feature.
"""
from repro.configs.base import ArchSpec, RECSYS_SHAPES, RecsysConfig

ROWS = 1 << 20

MODEL = RecsysConfig(
    name="xdeepfm", interaction="cin",
    n_sparse=39, embed_dim=10, mlp_dims=(400, 400), n_dense=13,
    vocab_sizes=(ROWS,) * 39, multi_hot=1, cin_dims=(200, 200, 200),
)

ARCH = ArchSpec(
    arch_id="xdeepfm", family="recsys", model=MODEL, shapes=RECSYS_SHAPES,
    source="arXiv:1803.05170", optimizer="adagrad",
)
