"""dien [arXiv:1809.03672].

embed_dim=18, behavior seq_len=100, GRU interest extractor dim=108,
AUGRU interest evolution, MLP 200-80. Item vocab hashed to 2^20 rows.
"""
from repro.configs.base import ArchSpec, RECSYS_SHAPES, RecsysConfig

ROWS = 1 << 20

MODEL = RecsysConfig(
    name="dien", interaction="augru",
    embed_dim=18, seq_len=100, gru_dim=108, mlp_dims=(200, 80), n_dense=8,
    vocab_sizes=(ROWS,), multi_hot=1,
)

ARCH = ArchSpec(
    arch_id="dien", family="recsys", model=MODEL, shapes=RECSYS_SHAPES,
    source="arXiv:1809.03672", optimizer="adam",
)
