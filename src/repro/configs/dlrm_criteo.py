"""dlrm-criteo — the paper's own Criteo workload (InTune §5, Meta DLRM).

26 sparse + 13 dense Criteo features, embed_dim=128, bottom MLP
512-256-128, top MLP 1024-1024-512-256-1. Rows hashed to 2^23 per table:
26 * 8,388,608 * 128 ≈ 27.9B embedding params — the paper's "25B+
parameters, most of which are in the embedding tables". Trained with
hybrid parallelism (tables row-sharded over `model`), optimizer adagrad.
Not one of the 40 assigned cells — an extra row in the dry-run matrix.
"""
from repro.configs.base import ArchSpec, DLRMConfig, RECSYS_SHAPES

MODEL = DLRMConfig(
    name="dlrm-criteo",
    n_sparse=26, n_dense=13, embed_dim=128,
    vocab_sizes=(1 << 23,) * 26,
    bottom_mlp=(512, 256, 128),
    top_mlp=(1024, 1024, 512, 256, 1),
    multi_hot=1,
    # §Perf-optimized defaults (EXPERIMENTS.md §Perf iter2): bf16 tables +
    # shard_map row-wise lookup; row-wise adagrad below. The paper-faithful
    # fp32/adagrad/GSPMD baseline is variant 0 in benchmarks/perf_hillclimb.
    param_dtype="bfloat16",
    tp_lookup=True,
    # 27.9B embedding params need every mesh axis:
    # 2^23 rows / 512 devices = 16384 rows per shard.
    sharding_overrides=(("table_rows", ("pod", "data", "model")),),
)

ARCH = ArchSpec(
    arch_id="dlrm-criteo", family="dlrm", model=MODEL, shapes=RECSYS_SHAPES,
    source="InTune paper §5 / arXiv:1906.00091", optimizer="rowwise_adagrad",
)
