"""gemma2-2b [arXiv:2408.00118].

26L d_model=2304 8H (GQA kv=4, head_dim=256) d_ff=9216 vocab=256000.
Local (sliding-window 4096) / global alternating layers, attn logit
softcap 50, final logit softcap 30, post-block norms, GeGLU, (1+w) RMSNorm,
embeddings scaled by sqrt(d). HYBRID attention -> long_500k cell RUNS for
this arch (the local half keeps an O(window) footprint at decode).
"""
from repro.configs.base import ArchSpec, LM_SHAPES, TransformerConfig

MODEL = TransformerConfig(
    name="gemma2-2b",
    n_layers=26, d_model=2304, n_heads=8, n_kv_heads=4, head_dim=256,
    d_ff=9216, vocab_size=256000,
    sliding_window=4096, local_global_alternating=True,
    attn_logit_softcap=50.0, final_logit_softcap=30.0,
    post_norm=True, scale_embed=True, act="gelu",
    rope_theta=10_000.0, tie_embeddings=True, remat="full", scan_block=2,
    # 8 q-heads / 4 kv-heads don't divide the 16-way model axis: attention
    # runs context-parallel (q seq over `model`); weights store TP over
    # head_dim (256/16); decode cache shards head_dim.
    sharding_overrides=(("head_dim", "model"), ("act_q_seq", "model"),
                        ("cache_head_dim", "model")),
)

ARCH = ArchSpec(
    arch_id="gemma2-2b", family="lm", model=MODEL, shapes=LM_SHAPES,
    source="arXiv:2408.00118", optimizer="adam",
    skipped_shapes=(),   # hybrid local/global: all four cells run
)
