"""graphsage-reddit [arXiv:1706.02216].

2 layers, d_hidden=128, mean aggregator, fanout 25-10 (training sampler
default; the minibatch_lg cell overrides to 15-10 per its shape spec).
Shapes carry their own graph sizes (cora / reddit / ogbn-products /
molecule batches).
"""
from repro.configs.base import ArchSpec, GNN_SHAPES, GNNConfig

MODEL = GNNConfig(
    name="graphsage-reddit", n_layers=2, d_hidden=128, n_classes=47,
    aggregator="mean", sample_sizes=(25, 10),
)

ARCH = ArchSpec(
    arch_id="graphsage-reddit", family="gnn", model=MODEL, shapes=GNN_SHAPES,
    source="arXiv:1706.02216", optimizer="adam",
)
