"""wide-deep [arXiv:1606.07792].

40 sparse features, embed_dim=32, deep MLP 1024-512-256, concat interaction,
wide linear arm over the same hashed features. Hashed vocab 2^20 rows per
feature (stacked tables: 40 x 1,048,576 x 32 ~ 1.3B embedding params).
multi_hot=4 models the multivalent features (user impressions/installs) the
paper describes — this is what exercises the EmbeddingBag path.
"""
from repro.configs.base import ArchSpec, RECSYS_SHAPES, RecsysConfig

ROWS = 1 << 20

MODEL = RecsysConfig(
    name="wide-deep", interaction="concat",
    n_sparse=40, embed_dim=32, mlp_dims=(1024, 512, 256), n_dense=13,
    vocab_sizes=(ROWS,) * 40, multi_hot=4,
    # §Perf-optimized defaults (same exchange as dlrm-criteo iter2):
    # all-axis row sharding + shard_map lookup + row-wise adagrad below.
    tp_lookup=True,
    sharding_overrides=(("table_rows", ("pod", "data", "model")),),
)

ARCH = ArchSpec(
    arch_id="wide-deep", family="recsys", model=MODEL, shapes=RECSYS_SHAPES,
    source="arXiv:1606.07792", optimizer="rowwise_adagrad",
)
