"""kimi-k2-1t-a32b [arXiv:2501.kimi2, paper-table].

61L d_model=7168 64H (GQA kv=8, head_dim=112) vocab=163840,
MoE: 384 routed experts top-8 (d_expert=2048) + 1 shared expert.
~1.03T total params / ~32B active. Optimizer: adafactor (factored second
moments) — the DESIGN.md §5 HBM-fit analysis for 512 chips depends on it.
"""
from repro.configs.base import ArchSpec, LM_SHAPES, TransformerConfig

MODEL = TransformerConfig(
    name="kimi-k2-1t-a32b",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8, head_dim=112,
    d_ff=2048, vocab_size=163840,
    n_experts=384, n_shared_experts=1, top_k=8, d_expert=2048,
    qkv_bias=False, rope_theta=50_000.0, tie_embeddings=False,
    act="silu", remat="full", attn_chunk=256,
    # Attention TP over the 64 q-heads (GSPMD splits the GQA (8,8) reshape
    # as an (8,2) tiling); 8 kv-heads < 16 auto-replicate (divisibility
    # rule). Decode cache shards head_dim (112/16=7) since kv can't.
    sharding_overrides=(("cache_head_dim", "model"),),
)

ARCH = ArchSpec(
    arch_id="kimi-k2-1t-a32b", family="lm", model=MODEL, shapes=LM_SHAPES,
    source="arXiv:2501.kimi2", optimizer="adafactor",
    skipped_shapes=(
        ("long_500k",
         "pure full-attention arch; long_500k runs only for "
         "sub-quadratic/hybrid attention per assignment"),
    ),
)
