"""Architecture registry: --arch <id> resolution.

Each assigned architecture lives in its own module exposing ARCH: ArchSpec.
Import is lazy so `import repro.configs` stays cheap.
"""
from __future__ import annotations

import importlib

from repro.configs.base import ArchSpec

_ARCH_MODULES = {
    # LM family
    "qwen2-moe-a2.7b": "repro.configs.qwen2_moe_a2_7b",
    "kimi-k2-1t-a32b": "repro.configs.kimi_k2_1t_a32b",
    "smollm-135m": "repro.configs.smollm_135m",
    "gemma2-2b": "repro.configs.gemma2_2b",
    "qwen2.5-32b": "repro.configs.qwen2_5_32b",
    # GNN
    "graphsage-reddit": "repro.configs.graphsage_reddit",
    # RecSys
    "wide-deep": "repro.configs.wide_deep",
    "xdeepfm": "repro.configs.xdeepfm",
    "dien": "repro.configs.dien",
    "bert4rec": "repro.configs.bert4rec",
    # The paper's own workload (Criteo DLRM)
    "dlrm-criteo": "repro.configs.dlrm_criteo",
}


def list_archs() -> list[str]:
    return sorted(_ARCH_MODULES)


def get_arch(arch_id: str) -> ArchSpec:
    if arch_id not in _ARCH_MODULES:
        raise KeyError(
            f"unknown arch {arch_id!r}; available: {list_archs()}")
    mod = importlib.import_module(_ARCH_MODULES[arch_id])
    return mod.ARCH
