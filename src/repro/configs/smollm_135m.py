"""smollm-135m [hf:HuggingFaceTB/SmolLM-135M] — llama-arch small dense.

30L d_model=576 9H (GQA kv=3, head_dim=64) d_ff=1536 vocab=49152.
"""
from repro.configs.base import ArchSpec, LM_SHAPES, TransformerConfig

MODEL = TransformerConfig(
    name="smollm-135m",
    n_layers=30, d_model=576, n_heads=9, n_kv_heads=3, head_dim=64,
    d_ff=1536, vocab_size=49152,
    rope_theta=10_000.0, tie_embeddings=True, act="silu", remat="full",
    # 9 q-heads / 3 kv-heads don't divide the 16-way model axis: attention
    # runs context-parallel (q seq over `model`); weights store TP over
    # head_dim (64/16); decode cache shards head_dim.
    sharding_overrides=(("head_dim", "model"), ("act_q_seq", "model"),
                        ("cache_head_dim", "model")),
)

ARCH = ArchSpec(
    arch_id="smollm-135m", family="lm", model=MODEL, shapes=LM_SHAPES,
    source="hf:HuggingFaceTB/SmolLM-135M", optimizer="adam",
    skipped_shapes=(
        ("long_500k",
         "pure full-attention arch; long_500k runs only for "
         "sub-quadratic/hybrid attention per assignment"),
    ),
)
