"""Mixture-of-Experts FFN with shard_map expert parallelism.

Design (see DESIGN.md §5): activations arrive sharded over the data axes and
*replicated* over the model axis; experts are sharded over the model axis.
Each model-rank routes (replicated, cheap), dispatches only the token-choices
destined to ITS local experts via a sort→gather formulation (no giant GShard
dispatch-mask einsum, no scatter in the forward), runs the expert GEMMs as a
batched einsum, combines with a scatter-add into its partial output, and one
psum over the model axis completes the block — the same single all-reduce a
Megatron TP MLP costs. Shared experts are tensor-parallel over the same axis
and fused into the same psum.

Capacity semantics: per-expert capacity C = ceil(T_local * top_k * cf / E)
(rounded up to a multiple of 8); token-choices beyond capacity are dropped
(GShard-style), their combine weight never applied.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.common.shardlib import compat_shard_map as _shard_map
from repro.models.layers import activation

P = jax.sharding.PartitionSpec


def capacity_for(t_local: int, top_k: int, n_experts: int, cf: float) -> int:
    c = int(-(-t_local * top_k * cf // n_experts))
    return max(8, -(-c // 8) * 8)


def init_moe_params(rng, n_layers, d_model, n_experts_padded, d_expert,
                    n_shared, dtype):
    """Stacked-over-layers MoE params + logical-axis tree."""
    k = jax.random.split(rng, 7)
    e, d, f = n_experts_padded, d_model, d_expert
    s = lambda *sh: sh
    params = {
        "router": jax.random.normal(k[0], (n_layers, d, e), jnp.float32) * d ** -0.5,
        "wi": jax.random.normal(k[1], s(n_layers, e, d, f), dtype) * d ** -0.5,
        "wg": jax.random.normal(k[2], s(n_layers, e, d, f), dtype) * d ** -0.5,
        "wo": jax.random.normal(k[3], s(n_layers, e, f, d), dtype) * f ** -0.5,
    }
    logical = {
        "router": ("layers", "embed", None),
        # expert dim -> model (EP); d_model dim -> fsdp (ZeRO-3 storage,
        # gathered per layer by the shard_map in_specs reshard)
        "wi": ("layers", "expert", "fsdp", "expert_mlp"),
        "wg": ("layers", "expert", "fsdp", "expert_mlp"),
        "wo": ("layers", "expert", "expert_mlp", "fsdp"),
    }
    if n_shared:
        fs = n_shared * d_expert
        params["shared"] = {
            "wi": jax.random.normal(k[4], (n_layers, d, fs), dtype) * d ** -0.5,
            "wg": jax.random.normal(k[5], (n_layers, d, fs), dtype) * d ** -0.5,
            "wo": jax.random.normal(k[6], (n_layers, fs, d), dtype) * fs ** -0.5,
        }
        logical["shared"] = {
            "wi": ("layers", "fsdp", "mlp"),
            "wg": ("layers", "fsdp", "mlp"),
            "wo": ("layers", "mlp", "fsdp"),
        }
    return params, logical


def _route(x, router_w, n_experts: int, top_k: int, norm_topk: bool):
    """Router in fp32. Padded experts (cols >= n_experts) get -inf logits."""
    logits = x.astype(jnp.float32) @ router_w  # (T, E_pad)
    e_pad = router_w.shape[-1]
    if e_pad > n_experts:
        pad_mask = jnp.arange(e_pad) >= n_experts
        logits = jnp.where(pad_mask, -1e30, logits)
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, top_k)  # (T, k)
    if norm_topk:
        topw = topw / jnp.maximum(jnp.sum(topw, -1, keepdims=True), 1e-9)
    return probs, topw, topi


def _aux_loss(probs, topi, n_experts: int):
    """Switch-style load-balance loss over the real (unpadded) experts."""
    t, k = topi.shape
    hits = jnp.zeros((probs.shape[-1],), jnp.float32).at[topi.reshape(-1)].add(1.0)
    frac_routed = hits[:n_experts] / (t * k)
    frac_prob = jnp.mean(probs[:, :n_experts], axis=0)
    return n_experts * jnp.sum(frac_routed * frac_prob)


def _dispatch_local(x, flat_e, flat_w, e_start, e_loc: int, cap: int):
    """Sort→gather dispatch of token-choices to this rank's local experts.

    Returns xbuf (e_loc, cap, D), wbuf (e_loc, cap), tok (e_loc, cap).
    Pure gathers in the forward (backward is a scatter-add, which XLA
    partitions fine since indices are rank-local).
    """
    tk = flat_e.shape[0]
    tok_of = jnp.arange(tk) // (tk // x.shape[0])
    local_e = jnp.where(
        (flat_e >= e_start) & (flat_e < e_start + e_loc),
        flat_e - e_start, e_loc)                       # e_loc == overflow bin
    order = jnp.argsort(local_e)                        # stable: groups experts
    counts = jnp.zeros((e_loc + 1,), jnp.int32).at[local_e].add(1)[:e_loc]
    starts = jnp.cumsum(counts) - counts                # exclusive
    slot_c = jnp.arange(cap)
    src = starts[:, None] + slot_c[None, :]             # (e_loc, cap)
    valid = slot_c[None, :] < jnp.minimum(counts, cap)[:, None]
    entry = order[jnp.minimum(src, tk - 1)]             # (e_loc, cap)
    tok = tok_of[entry]
    xbuf = x[tok] * valid[..., None].astype(x.dtype)
    wbuf = jnp.where(valid, flat_w[entry], 0.0)
    return xbuf, wbuf, tok


def _moe_local(x, p, *, cfg, e_start, e_loc: int, tp_axis: Optional[str],
               dp_axes: Tuple[str, ...]):
    """Per-device MoE block. x: (T_local, D). Returns (y, aux_loss)."""
    t, d = x.shape
    act = activation(cfg.act)
    probs, topw, topi = _route(x, p["router"], cfg.n_experts, cfg.top_k,
                               cfg.norm_topk_prob)
    aux = _aux_loss(probs, topi, cfg.n_experts)
    cap = capacity_for(t, cfg.top_k, max(cfg.n_experts, 1), cfg.capacity_factor)
    xbuf, wbuf, tok = _dispatch_local(
        x, topi.reshape(-1), topw.reshape(-1).astype(x.dtype), e_start, e_loc, cap)
    # Expert GEMMs: (e, c, d) x (e, d, f) -> (e, c, f)
    h = act(jnp.einsum("ecd,edf->ecf", xbuf, p["wg"])) * \
        jnp.einsum("ecd,edf->ecf", xbuf, p["wi"])
    out = jnp.einsum("ecf,efd->ecd", h, p["wo"])        # (e, c, d)
    out = out * wbuf[..., None]
    y = jnp.zeros((t, d), x.dtype).at[tok.reshape(-1)].add(
        out.reshape(-1, d))
    if "shared" in p:  # tensor-parallel shared experts, fused into same psum
        hs = act(x @ p["shared"]["wg"]) * (x @ p["shared"]["wi"])
        y = y + hs @ p["shared"]["wo"]
    if tp_axis is not None:
        y = jax.lax.psum(y, tp_axis)
    if dp_axes:
        aux = jax.lax.pmean(aux, dp_axes)
    return y, aux


def moe_ffn(x, p, cfg, mesh: Optional[jax.sharding.Mesh], e_pad: int):
    """MoE FFN over tokens x: (B, S, D) or (T, D). Returns (y, aux)."""
    orig_shape = x.shape
    x2 = x.reshape(-1, x.shape[-1])
    t = x2.shape[0]

    if mesh is None or cfg.moe_impl == "local":
        y, aux = _moe_local(x2, p, cfg=cfg, e_start=0, e_loc=e_pad,
                            tp_axis=None, dp_axes=())
        return y.reshape(orig_shape), aux

    names = mesh.axis_names
    tp_axis = "model" if "model" in names else None
    tp = mesh.shape.get("model", 1) if tp_axis else 1
    dp_axes = tuple(a for a in ("pod", "data") if a in names)
    dp = 1
    for a in dp_axes:
        dp *= mesh.shape[a]
    if t % max(dp, 1) != 0:  # e.g. decode batch 1: replicate over data
        dp_axes, dp = (), 1
    assert e_pad % max(tp, 1) == 0, (e_pad, tp)
    e_loc = e_pad // max(tp, 1)

    x_spec = P(dp_axes if len(dp_axes) > 1 else (dp_axes[0] if dp_axes else None), None)
    w_specs = {
        "router": P(None, None),
        "wi": P("model" if tp_axis else None, None, None),
        "wg": P("model" if tp_axis else None, None, None),
        "wo": P("model" if tp_axis else None, None, None),
    }
    if "shared" in p:
        w_specs["shared"] = {
            "wi": P(None, "model" if tp_axis else None),
            "wg": P(None, "model" if tp_axis else None),
            "wo": P("model" if tp_axis else None, None),
        }

    def fn(xl, pl):
        e_start = (jax.lax.axis_index(tp_axis) * e_loc) if tp_axis and tp > 1 \
            else 0
        return _moe_local(xl, pl, cfg=cfg, e_start=e_start, e_loc=e_loc,
                          tp_axis=tp_axis if tp > 1 else None,
                          dp_axes=dp_axes)

    y, aux = _shard_map(
        fn, mesh=mesh, in_specs=(x_spec, w_specs),
        out_specs=(x_spec, P()))(x2, p)
    return y.reshape(orig_shape), aux
