"""GraphSAGE (mean aggregator) in three execution regimes.

  - full-graph: edge-list message passing via jnp.take + jax.ops.segment_sum
    (JAX's BCOO can't shard a 62M-edge SpMM; segment ops over an edge-index
    ARE the system per the assignment). Edges shard over the data axes.
  - minibatch: dense-fanout sampled blocks (B, F1, F2, d) produced by
    data/sampler.py — pure batched tensor ops, shards over batch.
  - batched small graphs: padded per-graph edge lists + vmap.

Params per layer: W_self (d_in, d_out), W_neigh (d_in, d_out), bias.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import GNNConfig


def init_params(rng, cfg: GNNConfig, d_feat: int):
    dims = [d_feat] + [cfg.d_hidden] * (cfg.n_layers - 1) + [cfg.n_classes]
    dtype = jnp.dtype(cfg.param_dtype)
    layers, logical = [], []
    keys = jax.random.split(rng, cfg.n_layers)
    for l in range(cfg.n_layers):
        d_in, d_out = dims[l], dims[l + 1]
        k1, k2 = jax.random.split(keys[l])
        layers.append({
            "w_self": jax.random.normal(k1, (d_in, d_out), dtype) * d_in ** -0.5,
            "w_neigh": jax.random.normal(k2, (d_in, d_out), dtype) * d_in ** -0.5,
            "b": jnp.zeros((d_out,), dtype),
        })
        logical.append({
            "w_self": ("fsdp", None),
            "w_neigh": ("fsdp", None),
            "b": (None,),
        })
    return {"layers": tuple(layers)}, {"layers": tuple(logical)}


def _sage_combine(h_self, h_neigh, layer, *, final: bool):
    out = h_self @ layer["w_self"] + h_neigh @ layer["w_neigh"] + layer["b"]
    if not final:
        out = jax.nn.relu(out)
        # L2-normalize as in the paper (Hamilton et al. 2017, Alg. 1 line 7)
        out = out / jnp.maximum(
            jnp.linalg.norm(out, axis=-1, keepdims=True), 1e-6)
    return out


# ------------------------------------------------------------ full graph ---
def full_graph_forward(params, cfg: GNNConfig, x, edge_src, edge_dst,
                       n_nodes: int):
    """x: (N, d); edge arrays (E,) int32 (messages flow src -> dst)."""
    h = x
    n_layers = len(params["layers"])
    for l, layer in enumerate(params["layers"]):
        msg = jnp.take(h, edge_src, axis=0)                      # (E, d)
        agg = jax.ops.segment_sum(msg, edge_dst, num_segments=n_nodes)
        if cfg.aggregator == "mean":
            deg = jax.ops.segment_sum(
                jnp.ones_like(edge_dst, h.dtype), edge_dst,
                num_segments=n_nodes)
            agg = agg / jnp.maximum(deg, 1.0)[:, None]
        elif cfg.aggregator == "max":
            agg = jax.ops.segment_max(msg, edge_dst, num_segments=n_nodes)
        h = _sage_combine(h, agg, layer, final=(l == n_layers - 1))
    return h  # (N, n_classes) logits


def full_graph_loss(params, cfg, batch):
    logits = full_graph_forward(
        params, cfg, batch["x"], batch["edge_src"], batch["edge_dst"],
        batch["x"].shape[0])
    labels = batch["labels"]
    mask = (labels >= 0).astype(jnp.float32)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(
        logp, jnp.maximum(labels, 0)[:, None], axis=1)[:, 0]
    loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return loss, {"xent": loss}


# ------------------------------------------- dst-partitioned full graph ----
def full_graph_partitioned_loss(params, cfg: GNNConfig, batch, mesh):
    """§Perf hillclimb 3: dst-partitioned message passing via shard_map.

    Device k owns the node range [k*n_loc, (k+1)*n_loc) and every edge
    whose dst falls in it (the data pipeline buckets + pads edge shards;
    pad edges carry src = dst = -1). segment_sum lands directly in the
    local node range — the edge-sharded baseline instead psums node-sized
    PARTIALS (N x d per layer, measured 2.3 GiB/device on ogb_products).
    The only large collective left is one all_gather of the hidden state
    between layers (its transpose is the matching reduce-scatter).

    batch: x (N_pad, d) replicated; edge_src/edge_dst (n_shards, e_loc)
    int32 bucketed by dst; labels (N_pad,) sharded (-1 = masked/pad).
    """
    from repro.common.shardlib import compat_shard_map as _shard_map
    P = jax.sharding.PartitionSpec
    axes = tuple(a for a in ("pod", "data", "model") if a in mesh.axis_names)
    n_shards = 1
    for a in axes:
        n_shards *= mesh.shape[a]
    n_pad = batch["x"].shape[0]
    assert n_pad % n_shards == 0, (n_pad, n_shards)
    n_loc = n_pad // n_shards
    row_axes = axes if len(axes) > 1 else axes[0]
    n_layers = len(params["layers"])

    def fn(p, x, src, dst, labels):
        src, dst, labels = src[0], dst[0], labels  # drop shard dim
        flat = jnp.zeros((), jnp.int32)
        for a in axes:
            flat = flat * mesh.shape[a] + jax.lax.axis_index(a)
        node0 = flat * n_loc
        ok = (src >= 0).astype(x.dtype)
        ldst = jnp.clip(dst - node0, 0, n_loc - 1)
        h_full = x
        for l, layer in enumerate(p["layers"]):
            msg = jnp.take(h_full, jnp.clip(src, 0, n_pad - 1), axis=0)
            msg = msg * ok[:, None]
            agg = jax.ops.segment_sum(msg, ldst, num_segments=n_loc)
            if cfg.aggregator == "mean":
                deg = jax.ops.segment_sum(ok, ldst, num_segments=n_loc)
                agg = agg / jnp.maximum(deg, 1.0)[:, None]
            h_self = jax.lax.dynamic_slice_in_dim(h_full, node0, n_loc)
            h_loc = _sage_combine(h_self, agg, layer,
                                  final=(l == n_layers - 1))
            if l < n_layers - 1:
                h_full = jax.lax.all_gather(h_loc, axes, axis=0, tiled=True)
        # local masked CE over this shard's label slice
        logp = jax.nn.log_softmax(h_loc.astype(jnp.float32), axis=-1)
        mask = (labels >= 0).astype(jnp.float32)
        nll = -jnp.take_along_axis(
            logp, jnp.maximum(labels, 0)[:, None], axis=1)[:, 0]
        num = jax.lax.psum(jnp.sum(nll * mask), axes)
        den = jax.lax.psum(jnp.sum(mask), axes)
        return num / jnp.maximum(den, 1.0)

    loss = _shard_map(
        fn, mesh=mesh,
        in_specs=(jax.tree_util.tree_map(lambda _: P(), params),
                  P(None, None), P(row_axes, None), P(row_axes, None),
                  P(row_axes)),
        out_specs=P())(
        params, batch["x"], batch["edge_src"], batch["edge_dst"],
        batch["labels"])
    return loss, {"xent": loss}


# -------------------------------------------------------- sampled blocks ---
def minibatch_forward(params, cfg: GNNConfig, x0, neigh1, neigh2):
    """Dense-fanout 2-layer GraphSAGE (the assigned config is 2-layer).

    x0:     (B, d)          seed-node features
    neigh1: (B, F1, d)      1-hop neighbor features
    neigh2: (B, F1, F2, d)  2-hop neighbor features
    """
    l1, l2 = params["layers"]
    # layer 1 applied at depth-1 frontier: aggregate 2-hop into 1-hop nodes
    agg2 = jnp.mean(neigh2, axis=2)                              # (B, F1, d)
    h1 = _sage_combine(neigh1, agg2, l1, final=False)            # (B, F1, h)
    # layer 1 applied at the seeds themselves (aggregate 1-hop raw feats)
    agg1 = jnp.mean(neigh1, axis=1)                              # (B, d)
    h0 = _sage_combine(x0, agg1, l1, final=False)                # (B, h)
    # layer 2 at seeds: aggregate 1-hop hidden into seeds
    agg_h1 = jnp.mean(h1, axis=1)                                # (B, h)
    return _sage_combine(h0, agg_h1, l2, final=True)             # (B, C)


def minibatch_loss(params, cfg, batch):
    logits = minibatch_forward(params, cfg, batch["x0"], batch["neigh1"],
                               batch["neigh2"])
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=1)[:, 0]
    return jnp.mean(nll), {"xent": jnp.mean(nll)}


# --------------------------------------------------- batched small graphs --
def batched_graphs_forward(params, cfg: GNNConfig, x, edge_src, edge_dst,
                           node_mask):
    """x: (G, N, d); edges (G, E) int32 padded (pad edges point to node 0 with
    node_mask 0); node_mask: (G, N). Returns graph-level logits (G, C) via
    masked mean pooling."""
    def single(xg, src, dst, mask):
        h = full_graph_forward(params, cfg, xg, src, dst, xg.shape[0])
        denom = jnp.maximum(jnp.sum(mask), 1.0)
        return jnp.sum(h * mask[:, None], axis=0) / denom
    return jax.vmap(single)(x, edge_src, edge_dst, node_mask)


def batched_graphs_loss(params, cfg, batch):
    logits = batched_graphs_forward(
        params, cfg, batch["x"], batch["edge_src"], batch["edge_dst"],
        batch["node_mask"])
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=1)[:, 0]
    return jnp.mean(nll), {"xent": jnp.mean(nll)}
