"""Meta DLRM (Naumov et al.) — the paper's Criteo workload.

bottom-MLP(dense) -> embedding bags (26 categorical) -> pairwise
dot-interaction -> top-MLP -> CTR logit. The 25B-parameter configuration
in the paper is dominated by the embedding tables; they are row-sharded
over the `model` mesh axis (hybrid parallelism [49] in the paper).

The dot-interaction has a Pallas kernel (kernels/dot_interact.py); this
module uses the pure-jnp form, and train/train_step.py can swap in the
kernel via cfg (the kernels' ref.py oracles are exactly these functions).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import DLRMConfig
from repro.models.embedding import multifeature_bag, tp_multifeature_bag
from repro.models.recsys import apply_mlp, bce_loss, init_mlp


def init_params(rng, cfg: DLRMConfig):
    dtype = jnp.dtype(cfg.param_dtype)
    k = jax.random.split(rng, 3)
    rows = cfg.vocab_sizes[0]
    tables = jax.random.normal(
        k[0], (cfg.n_sparse, rows, cfg.embed_dim), dtype) \
        * cfg.embed_dim ** -0.5
    bottom, bot_lg = init_mlp(
        k[1], (cfg.n_dense,) + cfg.bottom_mlp, dtype)
    n_f = cfg.n_sparse + 1                      # +1: bottom-MLP output
    n_pairs = n_f * (n_f - 1) // 2
    top_in = n_pairs + cfg.bottom_mlp[-1]
    top, top_lg = init_mlp(k[2], (top_in,) + cfg.top_mlp, dtype)
    params = {"tables": tables, "bottom": bottom, "top": top}
    logical = {"tables": (None, "table_rows", "table_dim"),
               "bottom": bot_lg, "top": top_lg}
    return params, logical


def dot_interaction(feats):
    """feats: (B, F, D) -> (B, F*(F-1)/2) lower-triangle pairwise dots."""
    b, f, d = feats.shape
    gram = jnp.einsum("bfd,bgd->bfg", feats, feats)   # (B, F, F)
    ii, jj = jnp.tril_indices(f, k=-1)
    return gram[:, ii, jj]


def forward(params, cfg: DLRMConfig, batch, *, interact_fn=None, ctx=None):
    """batch: sparse_ids (B, 26, hot), dense (B, 13) -> logits (B,)."""
    dense_out = apply_mlp(params["bottom"],
                          batch["dense"].astype(params["tables"].dtype),
                          final_act=True)
    if cfg.tp_lookup and ctx is not None:
        emb = tp_multifeature_bag(params["tables"], batch["sparse_ids"],
                                  ctx.mesh)
    else:
        emb = multifeature_bag(params["tables"], batch["sparse_ids"])
    feats = jnp.concatenate([dense_out[:, None, :], emb], axis=1)  # (B,27,D)
    interact = (interact_fn or dot_interaction)(feats)
    top_in = jnp.concatenate([interact, dense_out], axis=-1)
    return apply_mlp(params["top"], top_in)[:, 0]


def loss_fn(params, cfg: DLRMConfig, batch, *, interact_fn=None, ctx=None):
    logit = forward(params, cfg, batch, interact_fn=interact_fn, ctx=ctx)
    loss = bce_loss(logit, batch["label"].astype(jnp.float32))
    return loss, {"bce": loss}


def score_candidates(params, cfg: DLRMConfig, user, cand_ids, *,
                     chunks: int = 25, ctx=None):
    """Retrieval scoring with the user side computed ONCE.

    Naively calling forward() per candidate chunk re-gathers the 25 user
    features x C rows from the sharded tables every chunk (measured
    13.6 GiB/device of collective traffic); only feature 0 (the item)
    actually varies, so we look up the user features once and gather just
    the candidate column per chunk.
    """
    dense_out = apply_mlp(params["bottom"],
                          user["dense"].astype(params["tables"].dtype),
                          final_act=True)                     # (1, D)
    user_emb = multifeature_bag(params["tables"], user["sparse_ids"])
    c = cand_ids.shape[0]
    assert c % chunks == 0

    def score_chunk(ids):
        if ctx is not None:
            ids = ctx.cs(ids, "candidates")
        cc = ids.shape[0]
        item_emb = jnp.take(params["tables"][0],
                            ids % cfg.vocab_sizes[0], axis=0)  # (cc, D)
        feats = jnp.concatenate([
            jnp.broadcast_to(dense_out, (cc, dense_out.shape[-1]))[:, None],
            item_emb[:, None],
            jnp.broadcast_to(user_emb[0, 1:][None],
                             (cc, cfg.n_sparse - 1, cfg.embed_dim)),
        ], axis=1)                                             # (cc, 27, D)
        interact = dot_interaction(feats)
        top_in = jnp.concatenate(
            [interact, jnp.broadcast_to(dense_out,
                                        (cc, dense_out.shape[-1]))], -1)
        return apply_mlp(params["top"], top_in)[:, 0]

    blocks = cand_ids.reshape(chunks, c // chunks)
    if ctx is not None:
        blocks = ctx.cs(blocks, None, "candidates")
    out = jax.lax.map(score_chunk, blocks)
    return out.reshape(c)
