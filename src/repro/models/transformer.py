"""Decoder-only transformer family covering the five assigned LM archs.

Features driven entirely by TransformerConfig:
  - GQA attention (custom_vjp flash — never materializes S x S, forward OR
    backward),
  - RoPE, optional QKV bias (Qwen), logit softcaps (Gemma-2),
  - local/global alternating sliding-window layers (Gemma-2) via a
    scan-block of 2 layers with STATIC windows,
  - dense SwiGLU/GeGLU or MoE FFN (shard_map EP, see moe.py),
  - scan-over-layers with stacked params + configurable remat policy
    (keeps HLO size O(1) in depth — essential for the 61/64-layer archs),
  - explicit activation sharding constraints via ShardCtx (scan carries
    otherwise lose batch sharding under GSPMD),
  - tied or untied LM head.

Param layout: plain nested dict; every weight stacked over layers on axis 0.
A parallel "logical axes" tree maps each dim to a sharding rule name
(common/shardlib.py).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.common.shardlib import ShardCtx
from repro.configs.base import TransformerConfig
from repro.models import moe as moe_lib
from repro.models.embedding import tp_embedding_lookup
from repro.models.layers import (
    apply_rope, chunked_attention, cross_entropy_loss, decode_attention,
    mlp_block, rms_norm, softcap)

EXPERT_PAD_TO = 16  # model-axis TP degree on the production mesh


def expert_pad(cfg: TransformerConfig) -> int:
    if not cfg.is_moe:
        return 0
    return -(-cfg.n_experts // EXPERT_PAD_TO) * EXPERT_PAD_TO


# ------------------------------------------------------------------ init ---
def init_params(rng, cfg: TransformerConfig):
    """Returns (params, logical_axes) with layer-stacked weights."""
    dtype = jnp.dtype(cfg.param_dtype)
    L, D, V = cfg.n_layers, cfg.d_model, cfg.vocab_size
    H, K, hd, F = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.d_ff
    keys = jax.random.split(rng, 12)
    nrm = lambda k, shape, fan_in: (
        jax.random.normal(k, shape, dtype) * fan_in ** -0.5)

    ln_init = jnp.zeros if cfg.post_norm else jnp.ones  # gemma: (1+w) conv.
    params = {
        "embed": {"table": nrm(keys[0], (V, D), D)},
        "blocks": {
            "ln1": ln_init((L, D), dtype),
            "ln2": ln_init((L, D), dtype),
            "attn": {
                "wq": nrm(keys[1], (L, D, H, hd), D),
                "wk": nrm(keys[2], (L, D, K, hd), D),
                "wv": nrm(keys[3], (L, D, K, hd), D),
                "wo": nrm(keys[4], (L, H, hd, D), H * hd),
            },
        },
        "final_ln": ln_init((D,), dtype),
    }
    logical = {
        "embed": {"table": ("vocab", "embed")},
        "blocks": {
            "ln1": ("layers", "embed"),
            "ln2": ("layers", "embed"),
            "attn": {
                "wq": ("layers", "fsdp", "heads", "head_dim"),
                "wk": ("layers", "fsdp", "kv_heads", "head_dim"),
                "wv": ("layers", "fsdp", "kv_heads", "head_dim"),
                "wo": ("layers", "heads", "head_dim", "fsdp"),
            },
        },
        "final_ln": ("embed",),
    }
    if cfg.qkv_bias:
        params["blocks"]["attn"]["bq"] = jnp.zeros((L, H, hd), dtype)
        params["blocks"]["attn"]["bk"] = jnp.zeros((L, K, hd), dtype)
        params["blocks"]["attn"]["bv"] = jnp.zeros((L, K, hd), dtype)
        logical["blocks"]["attn"]["bq"] = ("layers", "heads", "head_dim")
        logical["blocks"]["attn"]["bk"] = ("layers", "kv_heads", "head_dim")
        logical["blocks"]["attn"]["bv"] = ("layers", "kv_heads", "head_dim")
    if cfg.post_norm:
        params["blocks"]["ln1_post"] = ln_init((L, D), dtype)
        params["blocks"]["ln2_post"] = ln_init((L, D), dtype)
        logical["blocks"]["ln1_post"] = ("layers", "embed")
        logical["blocks"]["ln2_post"] = ("layers", "embed")
    if cfg.is_moe:
        mp, ml = moe_lib.init_moe_params(
            keys[5], L, D, expert_pad(cfg), cfg.d_expert,
            cfg.n_shared_experts, dtype)
        params["blocks"]["moe"] = mp
        logical["blocks"]["moe"] = ml
    else:
        params["blocks"]["mlp"] = {
            "wi": nrm(keys[6], (L, D, F), D),
            "wg": nrm(keys[7], (L, D, F), D),
            "wo": nrm(keys[8], (L, F, D), F),
        }
        logical["blocks"]["mlp"] = {
            "wi": ("layers", "fsdp", "mlp"),
            "wg": ("layers", "fsdp", "mlp"),
            "wo": ("layers", "mlp", "fsdp"),
        }
    if not cfg.tie_embeddings:
        params["lm_head"] = nrm(keys[9], (D, V), D)
        logical["lm_head"] = ("embed", "vocab")
    return params, logical


# -------------------------------------------------------------- helpers ----
def _group_windows(cfg: TransformerConfig) -> Tuple[int, ...]:
    """STATIC per-sublayer windows within one scan group.

    Gemma-2: scan_block=2, (local W, global 0). Others: (W,) or (0,).
    The window pattern must be periodic with scan_block — checked here.
    """
    if cfg.local_global_alternating:
        assert cfg.scan_block == 2, "alternation needs scan_block=2"
        return (cfg.sliding_window, 0)
    return (cfg.sliding_window,) * cfg.scan_block


def _scan_groups(cfg: TransformerConfig) -> int:
    assert cfg.n_layers % cfg.scan_block == 0, (cfg.n_layers, cfg.scan_block)
    return cfg.n_layers // cfg.scan_block


def _group_params(blocks, cfg: TransformerConfig):
    """(L, ...) stacked params -> (L/blk, blk, ...)."""
    blk = cfg.scan_block
    if blk == 1:
        return jax.tree_util.tree_map(
            lambda p: p.reshape((p.shape[0], 1) + p.shape[1:]), blocks)
    return jax.tree_util.tree_map(
        lambda p: p.reshape((p.shape[0] // blk, blk) + p.shape[1:]), blocks)


def _attn_proj(h, attn_p, cfg, ctx: ShardCtx):
    q = jnp.einsum("bsd,dhk->bshk", h, attn_p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", h, attn_p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", h, attn_p["wv"])
    if cfg.qkv_bias:
        q = q + attn_p["bq"]
        k = k + attn_p["bk"]
        v = v + attn_p["bv"]
    q = ctx.cs(q, "batch", "act_q_seq", "act_heads", "act_head_dim")
    k = ctx.cs(k, "batch", "act_kv_seq", "act_kv_heads", "act_head_dim")
    v = ctx.cs(v, "batch", "act_kv_seq", "act_kv_heads", "act_head_dim")
    return q, k, v


def _remat(fn, cfg: TransformerConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots)
    return jax.checkpoint(fn)  # "full": save nothing, recompute all


def _logits(x, params):
    head = params.get("lm_head")
    if head is None:
        return jnp.einsum("bsd,vd->bsv", x, params["embed"]["table"])
    return jnp.einsum("bsd,dv->bsv", x, head)


# -------------------------------------------------------------- forward ----
def forward(params, cfg: TransformerConfig, tokens, *,
            ctx: Optional[ShardCtx] = None, return_cache: bool = False):
    """Full-sequence forward. tokens: (B, S) int32.

    Returns (logits (B, S, V), aux) [, cache dict (L, B, S, K, hd)].
    """
    ctx = ctx or ShardCtx()
    B, S = tokens.shape
    x = tp_embedding_lookup(params["embed"]["table"], tokens, ctx.mesh)
    if cfg.scale_embed:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    x = ctx.cs(x, "batch", "act_seq", None)
    positions = jnp.arange(S)
    windows = _group_windows(cfg)
    plus1 = cfg.post_norm  # gemma-style (1+w) norms
    e_pad = expert_pad(cfg)

    def one_layer(x, blk, window: int):
        h = rms_norm(x, blk["ln1"], eps=cfg.norm_eps, plus_one=plus1)
        q, k, v = _attn_proj(h, blk["attn"], cfg, ctx)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        o = chunked_attention(
            q, k, v, window=window, causal=True,
            logit_cap=cfg.attn_logit_softcap, chunk=cfg.attn_chunk,
            scale=cfg.head_dim ** -0.5)
        o = ctx.cs(o, "batch", "act_q_seq", "act_heads", "act_head_dim")
        o = jnp.einsum("bshk,hkd->bsd", o, blk["attn"]["wo"])
        if cfg.post_norm:
            o = rms_norm(o, blk["ln1_post"], eps=cfg.norm_eps, plus_one=True)
        x = ctx.cs(x + o, "batch", "act_seq", None)
        h2 = rms_norm(x, blk["ln2"], eps=cfg.norm_eps, plus_one=plus1)
        if cfg.is_moe:
            m, aux = moe_lib.moe_ffn(h2, blk["moe"], cfg, ctx.mesh, e_pad)
        else:
            m = mlp_block(h2, blk["mlp"]["wi"], blk["mlp"]["wg"],
                          blk["mlp"]["wo"], cfg.act)
            aux = jnp.zeros((), jnp.float32)
        if cfg.post_norm:
            m = rms_norm(m, blk["ln2_post"], eps=cfg.norm_eps, plus_one=True)
        x = ctx.cs(x + m, "batch", "act_seq", None)
        return x, aux, (k, v)

    grouped = _group_params(params["blocks"], cfg)

    def body(carry, group):
        x, aux_sum = carry
        kvs = []
        for j in range(cfg.scan_block):
            blk = jax.tree_util.tree_map(lambda p: p[j], group)
            x, aux, kv = one_layer(x, blk, windows[j])
            aux_sum = aux_sum + aux
            kvs.append(kv)
        ys = (jnp.stack([k for k, _ in kvs]),
              jnp.stack([v for _, v in kvs])) if return_cache else None
        return (x, aux_sum), ys

    body = _remat(body, cfg)
    (x, aux_total), kvs = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), grouped)

    x = rms_norm(x, params["final_ln"], eps=cfg.norm_eps, plus_one=plus1)
    logits = _logits(x, params)
    logits = ctx.cs(logits, "batch", None, "act_vocab")
    if return_cache:
        # kvs: (groups, blk, B, S, K, hd) -> (L, B, S, K, hd)
        cache = {
            "k": kvs[0].reshape((cfg.n_layers,) + kvs[0].shape[2:]),
            "v": kvs[1].reshape((cfg.n_layers,) + kvs[1].shape[2:]),
        }
        return logits, cache, aux_total
    return logits, aux_total


def loss_fn(params, cfg: TransformerConfig, batch, *,
            ctx: Optional[ShardCtx] = None):
    """batch: {"tokens": (B, S), "labels": (B, S)}. Returns (loss, metrics)."""
    logits, aux = forward(params, cfg, batch["tokens"], ctx=ctx)
    xent = cross_entropy_loss(logits, batch["labels"],
                              final_cap=cfg.final_logit_softcap)
    loss = xent + cfg.router_aux_coef * aux
    return loss, {"xent": xent, "router_aux": aux}


# -------------------------------------------------------------- serving ----
def init_cache(cfg: TransformerConfig, batch: int, seq_len: int,
               dtype=jnp.bfloat16):
    shape = (cfg.n_layers, batch, seq_len, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def cache_logical_axes():
    return {"k": ("layers", "cache_batch", "cache_seq", "cache_kv_heads",
                  "cache_head_dim"),
            "v": ("layers", "cache_batch", "cache_seq", "cache_kv_heads",
                  "cache_head_dim")}


def prefill(params, cfg: TransformerConfig, tokens, *,
            ctx: Optional[ShardCtx] = None):
    """Prefill: forward over the prompt, return last-token logits + cache."""
    logits, cache, _ = forward(params, cfg, tokens, ctx=ctx,
                               return_cache=True)
    return logits[:, -1], cache


def decode_step(params, cfg: TransformerConfig, cache, tokens, pos, *,
                ctx: Optional[ShardCtx] = None):
    """One decode step. tokens: (B,) int32; pos: scalar int32 (same for the
    whole batch — continuous batching handled a level above).

    Returns (logits (B, V), new cache).
    """
    ctx = ctx or ShardCtx()
    B = tokens.shape[0]
    x = tp_embedding_lookup(params["embed"]["table"], tokens,
                            ctx.mesh)[:, None, :]     # (B, 1, D)
    if cfg.scale_embed:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    windows = _group_windows(cfg)
    plus1 = cfg.post_norm
    e_pad = expert_pad(cfg)
    pos_arr = jnp.asarray(pos)[None]

    def one_layer(x, blk, window, k_l, v_l):
        h = rms_norm(x, blk["ln1"], eps=cfg.norm_eps, plus_one=plus1)
        q, k, v = _attn_proj(h, blk["attn"], cfg, ctx)
        q = apply_rope(q, pos_arr, cfg.rope_theta)
        k = apply_rope(k, pos_arr, cfg.rope_theta)
        k_l = jax.lax.dynamic_update_slice(
            k_l, k.astype(k_l.dtype), (0, pos, 0, 0))
        v_l = jax.lax.dynamic_update_slice(
            v_l, v.astype(v_l.dtype), (0, pos, 0, 0))
        k_l = ctx.cs(k_l, "cache_batch", "cache_seq", "cache_kv_heads",
                     "cache_head_dim")
        v_l = ctx.cs(v_l, "cache_batch", "cache_seq", "cache_kv_heads",
                     "cache_head_dim")
        o = decode_attention(q, k_l, v_l, pos=pos, window=window,
                             logit_cap=cfg.attn_logit_softcap,
                             scale=cfg.head_dim ** -0.5)
        o = jnp.einsum("bshk,hkd->bsd", o.astype(x.dtype), blk["attn"]["wo"])
        if cfg.post_norm:
            o = rms_norm(o, blk["ln1_post"], eps=cfg.norm_eps, plus_one=True)
        x = x + o
        h2 = rms_norm(x, blk["ln2"], eps=cfg.norm_eps, plus_one=plus1)
        if cfg.is_moe:
            m, _ = moe_lib.moe_ffn(h2, blk["moe"], cfg, ctx.mesh, e_pad)
        else:
            m = mlp_block(h2, blk["mlp"]["wi"], blk["mlp"]["wg"],
                          blk["mlp"]["wo"], cfg.act)
        if cfg.post_norm:
            m = rms_norm(m, blk["ln2_post"], eps=cfg.norm_eps, plus_one=True)
        return ctx.cs(x + m, "cache_batch", None, None), k_l, v_l

    grouped = _group_params(params["blocks"], cfg)
    blk_sz = cfg.scan_block

    def regroup(c):
        return c.reshape((cfg.n_layers // blk_sz, blk_sz) + c.shape[1:])

    def body(x, xs):
        group, k_g, v_g = xs
        k_out, v_out = [], []
        for j in range(blk_sz):
            blk = jax.tree_util.tree_map(lambda p: p[j], group)
            x, k_l, v_l = one_layer(x, blk, windows[j], k_g[j], v_g[j])
            k_out.append(k_l)
            v_out.append(v_l)
        return x, (jnp.stack(k_out), jnp.stack(v_out))

    x, (new_k, new_v) = jax.lax.scan(
        body, x, (grouped, regroup(cache["k"]), regroup(cache["v"])))
    x = rms_norm(x, params["final_ln"], eps=cfg.norm_eps, plus_one=plus1)
    logits = _logits(x, params)
    logits = softcap(logits[:, 0].astype(jnp.float32),
                     cfg.final_logit_softcap)
    new_cache = {
        "k": new_k.reshape((cfg.n_layers,) + new_k.shape[2:]),
        "v": new_v.reshape((cfg.n_layers,) + new_v.shape[2:]),
    }
    return logits, new_cache
