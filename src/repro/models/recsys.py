"""The four assigned recsys architectures.

  wide-deep  [1606.07792]  embedding concat -> deep MLP, + wide linear arm
  xdeepfm    [1803.05170]  CIN (compressed interaction network) + DNN + linear
  dien       [1809.03672]  GRU interest extractor + AUGRU interest evolution
  bert4rec   [1904.06690]  bidirectional self-attn over item sequences

Input conventions (produced by data/synthetic.py and launch/input_specs):
  CTR models (wide-deep, xdeepfm):
     sparse_ids (B, n_sparse, multi_hot) int32 hashed, dense (B, n_dense) f32,
     label (B,) f32
  dien:   hist_ids (B, S) int32, hist_mask (B, S) f32, target_id (B,) int32,
          dense (B, n_dense) f32, label (B,)
  bert4rec: item_seq (B, S) int32 (MASK = n_items), labels (B, S) int32
          (-1 = unmasked position)
Retrieval: score_candidates(user_inputs, cand_ids (C,)) -> (C,) scores.

Embedding tables are stacked (F, V, D), row-sharded over `model`
(models/embedding.py). All MLPs are plain fp32/bf16 dense stacks.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from repro.configs.base import RecsysConfig
from repro.models.embedding import multifeature_bag


# ------------------------------------------------------------- mlp utils ---
def init_mlp(rng, dims: Sequence[int], dtype, final_bias=True):
    layers, logical = [], []
    keys = jax.random.split(rng, len(dims) - 1)
    for i in range(len(dims) - 1):
        layers.append({
            "w": jax.random.normal(keys[i], (dims[i], dims[i + 1]), dtype)
            * dims[i] ** -0.5,
            "b": jnp.zeros((dims[i + 1],), dtype),
        })
        logical.append({"w": ("fsdp", "mlp"), "b": ("mlp",)})
    return tuple(layers), tuple(logical)


def apply_mlp(layers, x, act=jax.nn.relu, final_act=False):
    for i, l in enumerate(layers):
        x = x @ l["w"] + l["b"]
        if final_act or i < len(layers) - 1:
            x = act(x)
    return x


def bce_loss(logit, label):
    logit = logit.astype(jnp.float32)
    loss = jnp.maximum(logit, 0) - logit * label + jnp.log1p(
        jnp.exp(-jnp.abs(logit)))
    return jnp.mean(loss)


# =============================================================== wide-deep ==
def init_wide_deep(rng, cfg: RecsysConfig):
    dtype = jnp.dtype(cfg.param_dtype)
    k = jax.random.split(rng, 4)
    rows = cfg.vocab_sizes[0]
    tables = jax.random.normal(
        k[0], (cfg.n_sparse, rows, cfg.embed_dim), dtype) * cfg.embed_dim ** -0.5
    wide = jax.random.normal(k[1], (cfg.n_sparse, rows), dtype) * 0.01
    deep_in = cfg.n_sparse * cfg.embed_dim + cfg.n_dense
    mlp, mlp_lg = init_mlp(k[2], (deep_in,) + cfg.mlp_dims + (1,), dtype)
    wide_dense = jax.random.normal(k[3], (cfg.n_dense, 1), dtype) * 0.01
    params = {"tables": tables, "wide": wide, "wide_dense": wide_dense,
              "mlp": mlp, "bias": jnp.zeros((), dtype)}
    logical = {"tables": (None, "table_rows", "table_dim"),
               "wide": (None, "table_rows"),
               "wide_dense": (None, None),
               "mlp": mlp_lg, "bias": ()}
    return params, logical


def _ctr_tables_lookup(params, cfg, batch, ctx):
    if cfg.tp_lookup and ctx is not None and ctx.mesh is not None:
        from repro.models.embedding import tp_multifeature_bag
        return tp_multifeature_bag(params["tables"], batch["sparse_ids"],
                                   ctx.mesh)
    return multifeature_bag(params["tables"], batch["sparse_ids"])


def wide_deep_forward(params, cfg: RecsysConfig, batch, ctx=None, emb=None):
    if emb is None:
        emb = _ctr_tables_lookup(params, cfg, batch, ctx)          # (B,F,D)
    deep_in = jnp.concatenate(
        [emb.reshape(emb.shape[0], -1),
         batch["dense"].astype(emb.dtype)], axis=-1)
    deep_logit = apply_mlp(params["mlp"], deep_in)[:, 0]
    # wide arm: per-feature scalar weights, multi-hot summed
    wide_w = jax.vmap(lambda t, i: jnp.take(t, i, axis=0),
                      in_axes=(0, 1), out_axes=1)(
        params["wide"], batch["sparse_ids"])          # (B, F, hot)
    wide_logit = jnp.sum(wide_w, axis=(1, 2)) + \
        (batch["dense"].astype(wide_w.dtype) @ params["wide_dense"])[:, 0]
    return deep_logit + wide_logit + params["bias"]


# ================================================================= xdeepfm ==
def init_xdeepfm(rng, cfg: RecsysConfig):
    dtype = jnp.dtype(cfg.param_dtype)
    k = jax.random.split(rng, 5)
    rows = cfg.vocab_sizes[0]
    tables = jax.random.normal(
        k[0], (cfg.n_sparse, rows, cfg.embed_dim), dtype) * cfg.embed_dim ** -0.5
    linear = jax.random.normal(k[1], (cfg.n_sparse, rows), dtype) * 0.01
    # CIN filters: layer k maps (H_{k-1} x m) interactions -> H_k maps
    cin, cin_lg = [], []
    h_prev, m = cfg.n_sparse, cfg.n_sparse
    kc = jax.random.split(k[2], len(cfg.cin_dims))
    for i, h in enumerate(cfg.cin_dims):
        cin.append(jax.random.normal(kc[i], (h, h_prev, m), dtype)
                   * (h_prev * m) ** -0.5)
        cin_lg.append(("mlp", None, None))
        h_prev = h
    dnn_in = cfg.n_sparse * cfg.embed_dim + cfg.n_dense
    dnn, dnn_lg = init_mlp(k[3], (dnn_in,) + cfg.mlp_dims + (1,), dtype)
    out_w = jax.random.normal(
        k[4], (sum(cfg.cin_dims), 1), dtype) * sum(cfg.cin_dims) ** -0.5
    params = {"tables": tables, "linear": linear, "cin": tuple(cin),
              "dnn": dnn, "cin_out": out_w, "bias": jnp.zeros((), dtype)}
    logical = {"tables": (None, "table_rows", "table_dim"),
               "linear": (None, "table_rows"),
               "cin": tuple(cin_lg), "dnn": dnn_lg,
               "cin_out": (None, None), "bias": ()}
    return params, logical


def xdeepfm_forward(params, cfg: RecsysConfig, batch, ctx=None, emb=None):
    x0 = emb if emb is not None else \
        _ctr_tables_lookup(params, cfg, batch, ctx)               # (B,m,D)
    # The 200 CIN filters don't divide the 16-way model axis, so the model
    # axis contributes NOTHING to the CIN under pure propagation — GSPMD
    # replicates the whole interaction network 16x (measured useful ratio
    # 0.06). Re-shard the CIN's batch over every axis instead (two ~40 MB
    # reshards around the block buy a 16x compute-parallelism win, §Perf 5).
    x0c = x0 if ctx is None else ctx.cs(x0, "act_all_batch", None, None)
    xk = x0c
    pooled = []
    for w in params["cin"]:
        # x_k[b,h,d] = sum_{i,j} W[h,i,j] * x_{k-1}[b,i,d] * x0[b,j,d],
        # associated as (contract i, then j).
        u = jnp.einsum("hij,bid->bhjd", w, xk)
        xk = jnp.einsum("bhjd,bjd->bhd", u, x0c)
        if ctx is not None:
            xk = ctx.cs(xk, "act_all_batch", None, None)
        pooled.append(jnp.sum(xk, axis=-1))                       # (B, H_k)
    cin_logit = (jnp.concatenate(pooled, axis=-1) @ params["cin_out"])[:, 0]
    dnn_in = jnp.concatenate(
        [x0.reshape(x0.shape[0], -1), batch["dense"].astype(x0.dtype)], -1)
    dnn_logit = apply_mlp(params["dnn"], dnn_in)[:, 0]
    lin_w = jax.vmap(lambda t, i: jnp.take(t, i, axis=0),
                     in_axes=(0, 1), out_axes=1)(
        params["linear"], batch["sparse_ids"])
    lin_logit = jnp.sum(lin_w, axis=(1, 2))
    return cin_logit + dnn_logit + lin_logit + params["bias"]


# ==================================================================== dien ==
def _gru_init(rng, d_in, d_h, dtype):
    k1, k2 = jax.random.split(rng)
    return {"w": jax.random.normal(k1, (d_in, 3 * d_h), dtype) * d_in ** -0.5,
            "u": jax.random.normal(k2, (d_h, 3 * d_h), dtype) * d_h ** -0.5,
            "b": jnp.zeros((3 * d_h,), dtype)}


def _gru_cell(p, x, h, a=None):
    """Standard GRU cell; if `a` (B,) given, AUGRU: update gate scaled by a.

    Gate order along the 3h axis: reset, update, candidate.
    """
    d_h = h.shape[-1]
    xw = x @ p["w"] + p["b"]
    hu = h @ p["u"]
    r = jax.nn.sigmoid(xw[..., :d_h] + hu[..., :d_h])
    z = jax.nn.sigmoid(xw[..., d_h:2 * d_h] + hu[..., d_h:2 * d_h])
    n = jnp.tanh(xw[..., 2 * d_h:] + r * hu[..., 2 * d_h:])
    if a is not None:
        z = z * a[:, None]
    return (1 - z) * h + z * n


def init_dien(rng, cfg: RecsysConfig):
    dtype = jnp.dtype(cfg.param_dtype)
    k = jax.random.split(rng, 6)
    rows = cfg.vocab_sizes[0]
    item_table = jax.random.normal(
        k[0], (rows, cfg.embed_dim), dtype) * cfg.embed_dim ** -0.5
    gru1 = _gru_init(k[1], cfg.embed_dim, cfg.gru_dim, dtype)
    gru2 = _gru_init(k[2], cfg.gru_dim, cfg.gru_dim, dtype)
    att_w = jax.random.normal(
        k[3], (cfg.gru_dim, cfg.embed_dim), dtype) * cfg.gru_dim ** -0.5
    mlp_in = cfg.gru_dim + cfg.embed_dim + cfg.n_dense
    mlp, mlp_lg = init_mlp(k[4], (mlp_in,) + cfg.mlp_dims + (1,), dtype)
    params = {"items": item_table, "gru1": gru1, "gru2": gru2,
              "att_w": att_w, "mlp": mlp}
    g_lg = {"w": ("fsdp", "mlp"), "u": ("fsdp", "mlp"), "b": ("mlp",)}
    logical = {"items": ("table_rows", "table_dim"), "gru1": g_lg,
               "gru2": g_lg, "att_w": (None, None), "mlp": mlp_lg}
    return params, logical


def dien_interest_states(params, hist_emb):
    """First GRU pass (target-independent). hist_emb: (B, S, D) -> (B, S, H)."""
    b = hist_emb.shape[0]
    h0 = jnp.zeros((b, params["gru1"]["u"].shape[0]), hist_emb.dtype)

    def step(h, x_t):
        h = _gru_cell(params["gru1"], x_t, h)
        return h, h
    _, states = jax.lax.scan(step, h0, jnp.swapaxes(hist_emb, 0, 1))
    return jnp.swapaxes(states, 0, 1)                             # (B, S, H)


def dien_evolve(params, states, target_emb, hist_mask):
    """Attention + AUGRU second pass. Returns final interest (B, H)."""
    scores = jnp.einsum("bsh,hd,bd->bs", states, params["att_w"], target_emb)
    scores = jnp.where(hist_mask > 0, scores, -1e30)
    att = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(
        states.dtype)                                             # (B, S)
    b = states.shape[0]
    h0 = jnp.zeros((b, params["gru2"]["u"].shape[0]), states.dtype)

    def step(h, xs):
        s_t, a_t = xs
        return _gru_cell(params["gru2"], s_t, h, a=a_t), None
    h_final, _ = jax.lax.scan(
        step, h0, (jnp.swapaxes(states, 0, 1), jnp.swapaxes(att, 0, 1)))
    return h_final


def dien_forward(params, cfg: RecsysConfig, batch, ctx=None):
    hist = jnp.take(params["items"], batch["hist_ids"], axis=0)   # (B,S,D)
    hist = hist * batch["hist_mask"][..., None].astype(hist.dtype)
    target = jnp.take(params["items"], batch["target_id"], axis=0)
    states = dien_interest_states(params, hist)
    interest = dien_evolve(params, states, target, batch["hist_mask"])
    feats = jnp.concatenate(
        [interest, target, batch["dense"].astype(interest.dtype)], -1)
    return apply_mlp(params["mlp"], feats)[:, 0]


# ================================================================ bert4rec ==
def init_bert4rec(rng, cfg: RecsysConfig):
    dtype = jnp.dtype(cfg.param_dtype)
    d, hN = cfg.embed_dim, cfg.n_heads
    k = jax.random.split(rng, 3 + cfg.n_blocks)
    # +MASK, +PAD, then pad to a multiple of 16 so the row-sharded table
    # divides the model axis (extra rows are never referenced)
    vocab = -(-(cfg.n_items + 2) // 16) * 16
    items = jax.random.normal(k[0], (vocab, d), dtype) * d ** -0.5
    pos = jax.random.normal(k[1], (cfg.seq_len, d), dtype) * 0.02
    blocks, blk_lg = [], []
    for i in range(cfg.n_blocks):
        kb = jax.random.split(k[2 + i], 5)
        blocks.append({
            "wqkv": jax.random.normal(kb[0], (d, 3, hN, d // hN), dtype) * d ** -0.5,
            "wo": jax.random.normal(kb[1], (hN, d // hN, d), dtype) * d ** -0.5,
            "ln1": jnp.ones((d,), dtype), "ln2": jnp.ones((d,), dtype),
            "ffn_in": jax.random.normal(kb[2], (d, 4 * d), dtype) * d ** -0.5,
            "ffn_b": jnp.zeros((4 * d,), dtype),
            "ffn_out": jax.random.normal(kb[3], (4 * d, d), dtype) * (4 * d) ** -0.5,
        })
        blk_lg.append({
            "wqkv": ("fsdp", None, "heads", "head_dim"),
            "wo": ("heads", "head_dim", "fsdp"),
            "ln1": ("embed",), "ln2": ("embed",),
            "ffn_in": ("fsdp", "mlp"), "ffn_b": ("mlp",),
            "ffn_out": ("mlp", "fsdp"),
        })
    params = {"items": items, "pos": pos, "blocks": tuple(blocks),
              "ln_f": jnp.ones((d,), dtype)}
    logical = {"items": ("table_rows", "table_dim"), "pos": ("seq", "embed"),
               "blocks": tuple(blk_lg), "ln_f": ("embed",)}
    return params, logical


def _layer_norm(x, w, eps=1e-6):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, -1, keepdims=True)
    var = jnp.var(x32, -1, keepdims=True)
    return ((x32 - mu) * jax.lax.rsqrt(var + eps) * w).astype(x.dtype)


def bert4rec_encode(params, cfg: RecsysConfig, item_seq, ctx=None):
    """item_seq: (B, S) -> hidden (B, S, D). Bidirectional attention (S=200
    is tiny; direct scores are fine)."""
    if cfg.tp_lookup and ctx is not None and ctx.mesh is not None:
        from repro.models.embedding import tp_embedding_lookup
        emb = tp_embedding_lookup(params["items"], item_seq, ctx.mesh)
    else:
        emb = jnp.take(params["items"], item_seq, axis=0)
    x = emb + params["pos"]
    for blk in params["blocks"]:
        h = _layer_norm(x, blk["ln1"])
        qkv = jnp.einsum("bsd,dthk->tbshk", h, blk["wqkv"])
        q, k, v = qkv[0], qkv[1], qkv[2]
        sc = jnp.einsum("bshk,bthk->bhst", q, k,
                        preferred_element_type=jnp.float32)
        sc = sc * (q.shape[-1] ** -0.5)
        p = jax.nn.softmax(sc, axis=-1).astype(v.dtype)
        o = jnp.einsum("bhst,bthk->bshk", p, v)
        x = x + jnp.einsum("bshk,hkd->bsd", o, blk["wo"])
        h2 = _layer_norm(x, blk["ln2"])
        f = jax.nn.gelu(h2 @ blk["ffn_in"] + blk["ffn_b"]) @ blk["ffn_out"]
        x = x + f
    return _layer_norm(x, params["ln_f"])


def bert4rec_forward(params, cfg: RecsysConfig, batch):
    """Masked-item logits over the full item vocab: (B, S, vocab).

    Only viable for small vocabs (smoke tests); production training uses
    the sampled-softmax loss below — a full softmax over 2^20 items at
    batch 65536 x 200 positions is ~5.5e16 bytes of logits.
    """
    hidden = bert4rec_encode(params, cfg, batch["item_seq"])
    return jnp.einsum("bsd,vd->bsv", hidden, params["items"])


def tp_sampled_scores(items, h, cand, mesh):
    """Candidate scores against a row-sharded item table via shard_map.

    items: (V, D) P('model', None); h: (B, M, D); cand: (B, M, C) int32,
    both sharded over the data axes. Each model-rank scores only rows it
    owns and the psum moves LOGITS (B, M, C — tiny) instead of gathered
    embeddings (B, M, C, D). Autodiff scatters d_items into the local row
    shard (§Perf hillclimb 2).
    """
    from repro.common.shardlib import compat_shard_map as _shard_map
    P = jax.sharding.PartitionSpec
    names = mesh.axis_names
    tp = mesh.shape.get("model", 1)
    v = items.shape[0]
    if tp == 1 or v % tp != 0:
        emb = jnp.take(items, cand, axis=0)
        return jnp.einsum("bmd,bmnd->bmn", h, emb)
    v_loc = v // tp
    dp_axes = tuple(a for a in ("pod", "data") if a in names)
    dp = 1
    for a in dp_axes:
        dp *= mesh.shape[a]
    lead = dp_axes if h.shape[0] % max(dp, 1) == 0 and dp > 1 else None
    if isinstance(lead, tuple) and len(lead) == 1:
        lead = lead[0]

    def f(tbl, hl, cl):
        row0 = jax.lax.axis_index("model") * v_loc
        lid = cl - row0
        ok = (lid >= 0) & (lid < v_loc)
        emb = jnp.take(tbl, jnp.clip(lid, 0, v_loc - 1), axis=0)
        emb = emb * ok[..., None].astype(emb.dtype)
        part = jnp.einsum("bmd,bmnd->bmn", hl, emb)
        return jax.lax.psum(part, "model")

    return _shard_map(
        f, mesh=mesh,
        in_specs=(P("model", None), P(lead, None, None),
                  P(lead, None, None)),
        out_specs=P(lead, None, None))(items, h, cand)


def bert4rec_sampled_logits(params, cfg: RecsysConfig, batch, ctx=None):
    """Sampled-softmax cloze logits at masked positions only.

    batch: item_seq (B, S); mask_pos (B, M) int32; mask_labels (B, M);
    neg_ids (B, M, N) pipeline-sampled uniform negatives.
    Returns logits (B, M, 1+N) — index 0 is the true item.
    """
    hidden = bert4rec_encode(params, cfg, batch["item_seq"], ctx=ctx)
    h = jnp.take_along_axis(
        hidden, batch["mask_pos"][..., None], axis=1)           # (B,M,D)
    cand = jnp.concatenate(
        [batch["mask_labels"][..., None], batch["neg_ids"]], -1)  # (B,M,1+N)
    if cfg.tp_lookup and ctx is not None and ctx.mesh is not None:
        return tp_sampled_scores(params["items"], h, cand, ctx.mesh)
    emb = jnp.take(params["items"], cand, axis=0)               # (B,M,1+N,D)
    return jnp.einsum("bmd,bmnd->bmn", h, emb)


# ----------------------------------------------------------- entrypoints ---
FORWARD = {"wide-deep": wide_deep_forward, "xdeepfm": xdeepfm_forward,
           "dien": dien_forward}
INIT = {"wide-deep": init_wide_deep, "xdeepfm": init_xdeepfm,
        "dien": init_dien, "bert4rec": init_bert4rec}


def ctr_loss(params, cfg: RecsysConfig, batch, forward_fn, ctx=None):
    logit = forward_fn(params, cfg, batch, ctx=ctx)
    loss = bce_loss(logit, batch["label"].astype(jnp.float32))
    return loss, {"bce": loss}


def bert4rec_loss(params, cfg: RecsysConfig, batch, ctx=None):
    """Sampled-softmax masked-item loss (true item at index 0)."""
    logits = bert4rec_sampled_logits(params, cfg, batch, ctx=ctx)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    mask = (batch["mask_labels"] >= 0).astype(jnp.float32)
    nll = -logp[..., 0]
    loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return loss, {"xent": loss}


def bert4rec_full_softmax_loss(params, cfg: RecsysConfig, batch):
    """Full-vocab cloze loss — smoke-test/small-vocab variant."""
    logits = bert4rec_forward(params, cfg, batch)
    labels = batch["labels"]
    mask = (labels >= 0).astype(jnp.float32)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(
        logp, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return loss, {"xent": loss}


def score_candidates(params, cfg: RecsysConfig, user_batch, cand_ids,
                     chunks: int = 1, ctx=None):
    """Retrieval: score ONE user against C candidates -> (C,) scores.

    chunks > 1 scores candidates in `chunks` sequential slabs (lax.map) —
    bounds the live working set for interaction-heavy models (xDeepFM's CIN
    over 1M broadcast candidates would otherwise materialize ~19 GiB of
    per-device intermediates). `ctx` (ShardCtx) re-pins the candidate axis
    after the reshape, which otherwise loses its sharding.
    """
    if chunks > 1:
        c = cand_ids.shape[0]
        assert c % chunks == 0, (c, chunks)
        blocks = cand_ids.reshape(chunks, c // chunks)
        if ctx is not None:
            blocks = ctx.cs(blocks, None, "candidates")

        def one(ids):
            if ctx is not None:
                ids = ctx.cs(ids, "candidates")
            return score_candidates(params, cfg, user_batch, ids, ctx=ctx)
        out = jax.lax.map(one, blocks)
        return out.reshape(c)
    c = cand_ids.shape[0]
    if cfg.name == "bert4rec":
        hidden = bert4rec_encode(params, cfg, user_batch["item_seq"])
        u = hidden[0, -1]                                   # (D,)
        cand = jnp.take(params["items"], cand_ids, axis=0)  # (C, D)
        return cand @ u
    if cfg.name == "dien":
        hist = jnp.take(params["items"], user_batch["hist_ids"], axis=0)
        hist = hist * user_batch["hist_mask"][..., None].astype(hist.dtype)
        states = dien_interest_states(params, hist)         # (1, S, H)
        states_c = jnp.broadcast_to(states, (c,) + states.shape[1:])
        mask_c = jnp.broadcast_to(user_batch["hist_mask"],
                                  (c, states.shape[1]))
        target = jnp.take(params["items"], cand_ids, axis=0)
        interest = dien_evolve(params, states_c, target, mask_c)
        dense = jnp.broadcast_to(user_batch["dense"],
                                 (c, user_batch["dense"].shape[-1]))
        feats = jnp.concatenate(
            [interest, target, dense.astype(interest.dtype)], -1)
        return apply_mlp(params["mlp"], feats)[:, 0]
    # CTR models: candidate replaces sparse feature 0. The USER-side
    # embeddings are computed once (re-gathering them per candidate costs
    # ~22 GiB/device of collectives on wide-deep/retrieval_cand); only
    # the candidate feature's embedding column is gathered per chunk.
    fwd = FORWARD[cfg.name]
    user_emb = multifeature_bag(params["tables"],
                                user_batch["sparse_ids"])   # (1, F, D)
    sp = jnp.broadcast_to(user_batch["sparse_ids"],
                          (c,) + user_batch["sparse_ids"].shape[1:])
    sp = sp.at[:, 0, :].set(cand_ids[:, None] % cfg.vocab_sizes[0])
    dense = jnp.broadcast_to(user_batch["dense"],
                             (c, user_batch["dense"].shape[-1]))
    cand_emb = jnp.take(params["tables"][0],
                        cand_ids % cfg.vocab_sizes[0], axis=0)  # (C, D)
    if cfg.multi_hot > 1:   # bag semantics: candidate id repeated per slot
        cand_emb = cand_emb * cfg.multi_hot
    emb = jnp.concatenate([
        cand_emb[:, None],
        jnp.broadcast_to(user_emb[0, 1:][None],
                         (c, cfg.n_sparse - 1, cfg.embed_dim))], axis=1)
    # NOTE: ctx deliberately NOT forwarded — inside the lax.map chunk loop
    # the act_all_batch constraint forces per-iteration reshards (measured
    # 16x FLOPs regression); candidates are already data-sharded.
    return fwd(params, cfg, {"sparse_ids": sp, "dense": dense}, emb=emb)
