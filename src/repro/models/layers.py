"""Shared neural-net layers: norms, RoPE, activations, chunked attention.

Pure JAX (no flax). All attention paths avoid materializing the full
S x S score matrix: training/prefill use an online-softmax scan over KV
blocks (flash-attention algorithm in jnp), decode uses direct attention
(scores are (B, H, 1, S) — small). This is what keeps the compile-time
memory analysis of the 32k prefill / 4k train cells bounded.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30  # large-but-finite; avoids NaN from (-inf) - (-inf)


# ---------------------------------------------------------------- norms ----
def rms_norm(x, weight, *, eps: float = 1e-6, plus_one: bool = False):
    """RMSNorm in fp32, cast back to input dtype.

    plus_one=True gives the Gemma convention `x * (1 + w)`.
    """
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    w = weight.astype(jnp.float32)
    y = y * (1.0 + w) if plus_one else y * w
    return y.astype(dtype)


def softcap(x, cap: float):
    """Gemma-2 logit soft-capping: cap * tanh(x / cap)."""
    if not cap:
        return x
    return cap * jnp.tanh(x / cap)


def activation(name: str):
    return {"silu": jax.nn.silu, "gelu": functools.partial(jax.nn.gelu, approximate=True),
            "relu": jax.nn.relu}[name]


# ----------------------------------------------------------------- rope ----
def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, n_heads, head_dim); positions: (..., S) int32."""
    hd = x.shape[-1]
    inv = rope_freqs(hd, theta)                          # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * inv  # (..., S, hd/2)
    cos = jnp.cos(ang)[..., None, :]                      # (..., S, 1, hd/2)
    sin = jnp.sin(ang)[..., None, :]
    x32 = x.astype(jnp.float32)
    x1, x2 = jnp.split(x32, 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------ attention ----
def _group_query(q, n_kv: int):
    """(B, S, H, hd) -> (B, S, K, G, hd) with H = K * G."""
    b, s, h, hd = q.shape
    return q.reshape(b, s, n_kv, h // n_kv, hd)


def _block_kv(x, chunk: int):
    """(B, Skv, K, hd) -> (nblk, B, K, chunk, hd), zero-padded tail."""
    b, skv, n_kv, hd = x.shape
    nblk = -(-skv // chunk)
    pad = nblk * chunk - skv
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
    return x.reshape(b, nblk, chunk, n_kv, hd).transpose(1, 0, 3, 2, 4)


def _block_mask(blk, chunk, skv, q_pos, causal, window):
    """(Sq, chunk) validity mask for kv block `blk` (static window)."""
    kv_pos = blk * chunk + jnp.arange(chunk)
    valid = kv_pos[None, :] < skv
    if causal:
        valid = valid & (kv_pos[None, :] <= q_pos[:, None])
    if window:
        valid = valid & (q_pos[:, None] - kv_pos[None, :] < window)
    return valid


def _flash_fwd(q, k, v, window, causal, logit_cap, chunk, scale, q_offset):
    """Returns (out_f32 (B,K,G,Sq,hd), lse (B,K,G,Sq))."""
    b, sq, h, hd = q.shape
    _, skv, n_kv, _ = k.shape
    g = h // n_kv
    qg = jnp.swapaxes(_group_query(q, n_kv), 1, 2)   # (B, K, Sq, G, hd)
    kb, vb = _block_kv(k, chunk), _block_kv(v, chunk)
    nblk = kb.shape[0]
    q_pos = q_offset + jnp.arange(sq)

    def body(carry, xs):
        m, l, acc = carry
        kblk, vblk, blk = xs
        s = jnp.einsum("bksgh,bkch->bkgsc", qg, kblk,
                       preferred_element_type=jnp.float32) * scale
        s = softcap(s, logit_cap)
        valid = _block_mask(blk, chunk, skv, q_pos, causal, window)
        s = jnp.where(valid[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        upd = jnp.einsum("bkgsc,bkch->bkgsh", p.astype(vblk.dtype), vblk,
                         preferred_element_type=jnp.float32)
        acc_new = acc * corr[..., None] + upd
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, n_kv, g, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, n_kv, g, sq), jnp.float32)
    a0 = jnp.zeros((b, n_kv, g, sq, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), (kb, vb, jnp.arange(nblk)))
    l_safe = jnp.maximum(l, 1e-30)
    out = acc / l_safe[..., None]
    lse = m + jnp.log(l_safe)
    return out, lse


def _ungroup(outg, b, sq, h, hd):
    """(B, K, G, Sq, hd) -> (B, Sq, H, hd)."""
    outg = jnp.swapaxes(outg, 2, 3)                   # (B, K, Sq, G, hd)
    outg = jnp.swapaxes(outg, 1, 2)                   # (B, Sq, K, G, hd)
    return outg.reshape(b, sq, h, hd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def _flash(q, k, v, window, causal, logit_cap, chunk, scale, q_offset):
    out, _ = _flash_fwd(q, k, v, window, causal, logit_cap, chunk, scale,
                        q_offset)
    b, sq, h, hd = q.shape
    return _ungroup(out, b, sq, h, hd).astype(q.dtype)


def _flash_vjp_fwd(q, k, v, window, causal, logit_cap, chunk, scale,
                   q_offset):
    out, lse = _flash_fwd(q, k, v, window, causal, logit_cap, chunk, scale,
                          q_offset)
    b, sq, h, hd = q.shape
    primal = _ungroup(out, b, sq, h, hd).astype(q.dtype)
    return primal, (q, k, v, out, lse)


def _flash_vjp_bwd(window, causal, logit_cap, chunk, scale, q_offset,
                   res, dout):
    """FlashAttention backward: recompute scores per kv block from saved
    (q, k, v, out, lse); O(B*K*G*Sq*chunk) live scores only."""
    q, k, v, out, lse = res
    b, sq, h, hd = q.shape
    _, skv, n_kv, _ = k.shape
    g = h // n_kv
    qg = jnp.swapaxes(_group_query(q, n_kv), 1, 2)    # (B,K,Sq,G,hd)
    dog = jnp.swapaxes(jnp.swapaxes(
        dout.reshape(b, sq, n_kv, g, hd), 1, 2), 2, 3)  # (B,K,G,Sq,hd) f32?
    dog = dog.astype(jnp.float32)
    kb, vb = _block_kv(k, chunk), _block_kv(v, chunk)
    nblk = kb.shape[0]
    q_pos = q_offset + jnp.arange(sq)
    # D_i = sum_d dout_i * out_i  (out saved in f32, pre-cast)
    dsum = jnp.sum(dog * out, axis=-1)                # (B,K,G,Sq)

    def body(dq_acc, xs):
        kblk, vblk, blk = xs
        s_raw = jnp.einsum("bksgh,bkch->bkgsc", qg, kblk,
                           preferred_element_type=jnp.float32) * scale
        s = softcap(s_raw, logit_cap)
        valid = _block_mask(blk, chunk, skv, q_pos, causal, window)
        s = jnp.where(valid[None, None, None], s, NEG_INF)
        p = jnp.exp(s - lse[..., None])               # (B,K,G,Sq,c)
        dp = jnp.einsum("bkgsh,bkch->bkgsc", dog, vblk,
                        preferred_element_type=jnp.float32)
        ds = p * (dp - dsum[..., None])
        if logit_cap:
            ds = ds * (1.0 - jnp.square(s / logit_cap))
        ds = jnp.where(valid[None, None, None], ds, 0.0) * scale
        dq_blk = jnp.einsum("bkgsc,bkch->bksgh", ds, kblk,
                            preferred_element_type=jnp.float32)
        dk_blk = jnp.einsum("bkgsc,bksgh->bkch", ds, qg,
                            preferred_element_type=jnp.float32)
        dv_blk = jnp.einsum("bkgsc,bkgsh->bkch", p, dog,
                            preferred_element_type=jnp.float32)
        return dq_acc + dq_blk, (dk_blk, dv_blk)

    dq0 = jnp.zeros((b, n_kv, sq, g, hd), jnp.float32)
    dq, (dk_b, dv_b) = jax.lax.scan(body, dq0, (kb, vb, jnp.arange(nblk)))
    dq = jnp.swapaxes(dq, 1, 2).reshape(b, sq, h, hd).astype(q.dtype)
    # (nblk, B, K, c, hd) -> (B, Skv(+pad), K, hd), trim pad
    def unblock(xb):
        xb = xb.transpose(1, 0, 3, 2, 4).reshape(b, nblk * chunk, n_kv, hd)
        return xb[:, :skv]
    dk = unblock(dk_b).astype(k.dtype)
    dv = unblock(dv_b).astype(v.dtype)
    return dq, dk, dv


_flash.defvjp(_flash_vjp_fwd, _flash_vjp_bwd)


def chunked_attention(q, k, v, *, q_offset: int = 0, window: int = 0,
                      causal: bool = True, logit_cap: float = 0.0,
                      chunk: int = 512, scale: Optional[float] = None):
    """FlashAttention in pure JAX (custom_vjp; never materializes S x S).

    q: (B, Sq, H, hd); k, v: (B, Skv, K, hd) with GQA groups G = H // K.
    `window` is a STATIC python int: > 0 = sliding window, 0 = unbounded.
    q_offset is the absolute position of q[0].
    """
    _, _, _, hd = q.shape
    scale = scale if scale is not None else hd ** -0.5
    chunk = min(chunk, k.shape[1])
    return _flash(q, k, v, int(window), bool(causal), float(logit_cap),
                  int(chunk), float(scale), int(q_offset))


def decode_attention(q, k, v, *, pos, window: int = 0,
                     logit_cap: float = 0.0, scale: Optional[float] = None):
    """Single-token attention over a KV cache.

    q: (B, 1, H, hd); k, v: (B, S, K, hd); pos: scalar int32 — index of the
    token being decoded (cache entries > pos are garbage/unwritten).
    `window` is a STATIC python int (> 0 = sliding window, 0 = unbounded).
    Scores are (B, K, G, S): linear in cache length, no chunking needed.
    """
    b, sq, h, hd = q.shape
    assert sq == 1
    _, s, n_kv, _ = k.shape
    scale = scale if scale is not None else hd ** -0.5
    qg = _group_query(q, n_kv)[:, 0]                  # (B, K, G, hd)
    sc = jnp.einsum("bkgh,bskh->bkgs", qg, k,
                    preferred_element_type=jnp.float32) * scale
    sc = softcap(sc, logit_cap)
    kv_pos = jnp.arange(s)
    valid = kv_pos <= pos
    if window:
        valid = valid & (pos - kv_pos < window)
    sc = jnp.where(valid[None, None, None], sc, NEG_INF)
    p = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bkgs,bskh->bkgh", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, 1, h, hd).astype(q.dtype)


# -------------------------------------------------------------- linears ----
def dense(x, w, b=None):
    y = jnp.einsum("...d,df->...f", x, w)
    if b is not None:
        y = y + b
    return y


def mlp_block(x, wi, wg, wo, act_name: str):
    """Gated MLP (SwiGLU / GeGLU)."""
    act = activation(act_name)
    h = act(dense(x, wg)) * dense(x, wi)
    return dense(h, wo)


def cross_entropy_loss(logits, labels, *, final_cap: float = 0.0,
                       z_loss: float = 0.0):
    """Mean token cross-entropy in fp32; labels < 0 are masked out."""
    logits = softcap(logits.astype(jnp.float32), final_cap)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if z_loss:
        nll = nll + z_loss * jnp.square(lse)
    mask = (labels >= 0).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
