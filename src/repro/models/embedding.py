"""Sparse-embedding substrate for the recsys/DLRM families.

JAX has no native EmbeddingBag and only BCOO sparse — so this module IS the
system: multi-hot embedding-bag built from `jnp.take` + `jax.ops.segment_sum`
(the taxonomy-specified pattern), with row-sharded tables over the `model`
mesh axis (the paper's "hybrid parallelism [49]" layout for DLRM).

Two layouts are supported:
  - `stacked`: all n_sparse tables share one vocab size -> a single
    (n_sparse, rows, dim) array (best for sharding + the Pallas kernel path).
  - `ragged`: per-feature vocab sizes -> one (rows_f, dim) array per feature.
The assigned recsys configs use `stacked` with hashed ids (hash % rows), the
standard industrial trick (QR-hashing is the documented extension).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp


def init_table(rng, rows: int, dim: int, dtype=jnp.float32, scale=None):
    scale = scale if scale is not None else dim ** -0.5
    return jax.random.normal(rng, (rows, dim), dtype) * scale


def init_stacked_tables(rng, n_tables: int, rows: int, dim: int,
                        dtype=jnp.float32):
    """(n_tables, rows, dim); logical axes (None, 'table_rows', 'table_dim')."""
    return (jax.random.normal(rng, (n_tables, rows, dim), dtype) * dim ** -0.5,
            (None, "table_rows", "table_dim"))


def embedding_lookup(table, ids):
    """Plain single-hot lookup. table: (V, D); ids: (...) int32 -> (..., D)."""
    return jnp.take(table, ids, axis=0)


def tp_embedding_lookup(table, ids, mesh):
    """Vocab-sharded lookup via shard_map with SHARDED gradients.

    GSPMD partitions the forward gather of a vocab-sharded table fine, but
    its transpose materializes a full (V, D) f32 scatter target on every
    device (observed 4.4 GiB/device for the kimi-k2 vocab). Inside
    shard_map, each model-rank gathers rows it owns (masked) + psum; the
    autodiff transpose then scatters into the LOCAL (V/tp, D) shard only.

    table: (V, D) sharded P('model', None); ids: (B, ...) int32 sharded
    over the data axes. Falls back to plain take when no usable mesh.
    """
    if mesh is None or "model" not in mesh.axis_names:
        return jnp.take(table, ids, axis=0)
    tp = mesh.shape["model"]
    v = table.shape[0]
    if tp == 1 or v % tp != 0:
        return jnp.take(table, ids, axis=0)
    v_loc = v // tp

    from repro.common.shardlib import compat_shard_map as _shard_map
    P = jax.sharding.PartitionSpec

    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp = 1
    for a in dp_axes:
        dp *= mesh.shape[a]
    lead = dp_axes if ids.shape[0] % max(dp, 1) == 0 and dp > 1 else None
    if isinstance(lead, tuple) and len(lead) == 1:
        lead = lead[0]
    ids_spec = P(lead, *([None] * (ids.ndim - 1)))
    out_spec = P(lead, *([None] * ids.ndim))

    def f(tbl, idl):
        row0 = jax.lax.axis_index("model") * v_loc
        lid = idl - row0
        ok = (lid >= 0) & (lid < v_loc)
        e = jnp.take(tbl, jnp.clip(lid, 0, v_loc - 1), axis=0)
        e = e * ok[..., None].astype(e.dtype)
        return jax.lax.psum(e, "model")

    return _shard_map(f, mesh=mesh, in_specs=(P("model", None), ids_spec),
                      out_specs=out_spec)(table, ids)


def embedding_bag(table, ids, *, combiner: str = "sum", weights=None):
    """EmbeddingBag over the last axis of ids.

    table: (V, D); ids: (..., bag) int32 -> (..., D).
    combiner: "sum" | "mean" | "max". `weights` (..., bag) optional per-id
    weights (sum/mean only).
    """
    emb = jnp.take(table, ids, axis=0)          # (..., bag, D)
    if weights is not None:
        emb = emb * weights[..., None].astype(emb.dtype)
    if combiner == "sum":
        return jnp.sum(emb, axis=-2)
    if combiner == "mean":
        return jnp.mean(emb, axis=-2)
    if combiner == "max":
        return jnp.max(emb, axis=-2)
    raise ValueError(combiner)


def ragged_embedding_bag(table, ids, segment_ids, n_segments: int, *,
                         combiner: str = "sum"):
    """Ragged EmbeddingBag: flat ids + segment ids (torch-EmbeddingBag shape).

    table: (V, D); ids: (N,) int32; segment_ids: (N,) int32 sorted.
    Returns (n_segments, D). This is the `jnp.take` + `segment_sum`
    formulation the assignment calls for.
    """
    emb = jnp.take(table, ids, axis=0)          # (N, D)
    if combiner == "max":
        return jax.ops.segment_max(emb, segment_ids, num_segments=n_segments)
    out = jax.ops.segment_sum(emb, segment_ids, num_segments=n_segments)
    if combiner == "mean":
        cnt = jax.ops.segment_sum(jnp.ones_like(segment_ids, jnp.float32),
                                  segment_ids, num_segments=n_segments)
        out = out / jnp.maximum(cnt, 1.0)[..., None]
    return out


def multifeature_bag(tables, ids, *, combiner: str = "sum"):
    """Stacked-table multi-hot lookup.

    tables: (F, V, D); ids: (B, F, bag) int32 (already hashed mod V).
    Returns (B, F, D). Contracts the bag axis per feature.
    """
    f = tables.shape[0]
    # vmap over the feature axis so each feature reads its own table.
    def per_feature(tbl, idf):      # tbl: (V, D); idf: (B, bag)
        return embedding_bag(tbl, idf, combiner=combiner)
    out = jax.vmap(per_feature, in_axes=(0, 1), out_axes=1)(
        tables, ids)                # (B, F, D)
    return out


def tp_multifeature_bag(tables, ids, mesh, *, combiner: str = "sum"):
    """Fully-row-sharded stacked-table lookup via shard_map (§Perf 1).

    Rows shard over EVERY mesh axis (Meta row-wise table sharding — the
    only layout where neither the table nor its gradient is ever
    replicated). The exchange per step:
      1. all_gather the int32 ids over the data axes (cheap: ids are tiny),
      2. each device looks up the FULL batch against its local row shard
         (masked gather, zero elsewhere),
      3. psum_scatter over the data axes returns each data-rank its own
         batch slice, already summed; one psum over `model` finishes.
    Wire bytes ~ one pass of the (B, F, D) embeddings in table dtype,
    vs GSPMD's full-batch f32 all-reduce + all-to-all (measured 12x
    reduction on dlrm-criteo/train_batch). The autodiff transpose is
    all_gather(d_out) + LOCAL scatter into the row shard, so table grads
    stay sharded and the optimizer runs shard-local.

    tables: (F, V, D) sharded P(None, (pod, data, model), None);
    ids: (B, F, hot) sharded over the data axes.
    """
    names = getattr(mesh, "axis_names", ()) if mesh is not None else ()
    if "model" not in names:
        return multifeature_bag(tables, ids, combiner=combiner)
    shard_axes = tuple(a for a in ("pod", "data", "model") if a in names)
    n_shards = 1
    for a in shard_axes:
        n_shards *= mesh.shape[a]
    v = tables.shape[1]
    dp_axes = tuple(a for a in ("pod", "data") if a in names)
    dp = 1
    for a in dp_axes:
        dp *= mesh.shape[a]
    if n_shards == 1 or v % n_shards != 0 or ids.shape[0] % max(dp, 1):
        return multifeature_bag(tables, ids, combiner=combiner)
    v_loc = v // n_shards

    from repro.common.shardlib import compat_shard_map as _shard_map
    P = jax.sharding.PartitionSpec

    lead = dp_axes if len(dp_axes) > 1 else (dp_axes[0] if dp_axes else None)
    ids_spec = P(lead, None, None)
    row_axes = shard_axes if len(shard_axes) > 1 else shard_axes[0]

    hot = ids.shape[-1]

    def _local_ids(idl):
        """(full-batch local ids, validity mask) for this row shard."""
        flat = jnp.zeros((), jnp.int32)
        for a in shard_axes:   # major-to-minor = shard_axes order
            flat = flat * mesh.shape[a] + jax.lax.axis_index(a)
        row0 = flat * v_loc
        if dp > 1:
            ids_full = jax.lax.all_gather(idl, dp_axes, axis=0, tiled=True)
        else:
            ids_full = idl
        lid = ids_full - row0
        ok = (lid >= 0) & (lid < v_loc)
        return jnp.clip(lid, 0, v_loc - 1), ok

    def fwd_local(tbl, idl):         # tbl: (F, v_loc, D); idl: (B_loc,F,hot)
        lid, ok = _local_ids(idl)

        def per_feature(t_f, id_f, ok_f):     # (v_loc, D), (B, hot)
            e = jnp.take(t_f, id_f, axis=0)   # (B, hot, D)
            e = e * ok_f[..., None].astype(e.dtype)
            return jnp.sum(e, axis=-2)
        out = jax.vmap(per_feature, in_axes=(0, 1, 1), out_axes=1)(
            tbl, lid, ok)                      # (B, F, D) partial
        if dp > 1:
            out = jax.lax.psum_scatter(out, dp_axes, scatter_dimension=0,
                                       tiled=True)   # (B_loc, F, D)
        out = jax.lax.psum(out, "model")
        if combiner == "mean":
            out = out / hot
        return out

    def bwd_local(d_out, idl):
        """Explicit transpose: bf16 all-gather of d_out + LOCAL scatter.
        (XLA's auto-transpose fuses the optimizer's f32 convert INTO the
        gather — 2x the wire bytes; measured on dlrm-criteo.)"""
        lid, ok = _local_ids(idl)
        g = d_out.astype(tables.dtype)
        if combiner == "mean":
            g = g / hot
        if dp > 1:
            g = jax.lax.all_gather(g, dp_axes, axis=0, tiled=True)

        def per_feature(id_f, ok_f, g_f):     # (B, hot), (B, hot), (B, D)
            upd = jnp.broadcast_to(g_f[:, None, :],
                                   (g_f.shape[0], hot, g_f.shape[1]))
            upd = upd * ok_f[..., None].astype(upd.dtype)
            return jnp.zeros((v_loc, g_f.shape[1]), g_f.dtype).at[
                id_f.reshape(-1)].add(upd.reshape(-1, g_f.shape[1]))
        return jax.vmap(per_feature, in_axes=(1, 1, 1), out_axes=0)(
            lid, ok, g)                        # (F, v_loc, D)

    fwd_sm = _shard_map(fwd_local, mesh=mesh,
                        in_specs=(P(None, row_axes, None), ids_spec),
                        out_specs=ids_spec)
    bwd_sm = _shard_map(bwd_local, mesh=mesh,
                        in_specs=(ids_spec, ids_spec),
                        out_specs=P(None, row_axes, None))

    @jax.custom_vjp
    def lookup(tbl, idl):
        return fwd_sm(tbl, idl)

    def lookup_fwd(tbl, idl):
        return fwd_sm(tbl, idl), idl

    def lookup_bwd(idl, d_out):
        return bwd_sm(d_out, idl), None

    lookup.defvjp(lookup_fwd, lookup_bwd)
    return lookup(tables, ids)


def hash_ids(raw_ids, rows: int):
    """Cheap multiplicative hash into the table row space (mod rows)."""
    h = raw_ids.astype(jnp.uint32) * jnp.uint32(2654435761)
    return (h % jnp.uint32(rows)).astype(jnp.int32)
