from repro.common import pytree, shardlib  # noqa: F401
