"""Logical-axis sharding rules (flax.partitioning-style, dependency-free).

Every model annotates each parameter / activation dimension with a *logical*
axis name ("vocab", "mlp", "batch", ...). A rules table maps logical names to
physical mesh axes. This indirection is what lets one model definition run on
the single-pod (data, model) mesh, the multi-pod (pod, data, model) mesh, and
the 1-device CPU smoke-test mesh without touching model code — and it is the
knob the §Perf hillclimbs turn.
"""
from __future__ import annotations

from typing import Mapping, Optional, Sequence, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axis = Union[str, Sequence[str], None]


_SHARD_MAP = None        # (fn, extra-kwargs) resolved once on first use
_SHARD_MAP_KW: dict = {}


def compat_shard_map(f, *, mesh, in_specs, out_specs):
    """`jax.shard_map` across JAX versions, replication check disabled.

    The function moved from `jax.experimental.shard_map` to the top level,
    and the check kwarg was renamed `check_rep` -> `check_vma`; dispatch on
    the live signature (resolved once) so model code runs on any JAX.
    """
    global _SHARD_MAP, _SHARD_MAP_KW
    if _SHARD_MAP is None:
        import inspect

        try:
            from jax import shard_map as _sm
        except ImportError:  # pragma: no cover
            from jax.experimental.shard_map import shard_map as _sm
        params = inspect.signature(_sm).parameters
        if "check_vma" in params:
            _SHARD_MAP_KW = {"check_vma": False}
        elif "check_rep" in params:
            _SHARD_MAP_KW = {"check_rep": False}
        _SHARD_MAP = _sm
    return _SHARD_MAP(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **_SHARD_MAP_KW)

# Default physical mapping. "fsdp" is the weight-sharding (ZeRO-3) axis;
# "batch"/"edges"/"tokens" are activation data axes. "pod" composes with
# "data" so the multi-pod mesh gets hierarchical DP for free.
DEFAULT_RULES: dict[str, Axis] = {
    # activations
    "batch": ("pod", "data"),
    "seq": None,
    # Megatron-style sequence parallelism: the residual stream between
    # blocks shards its seq dim over `model` (norms/adds local; GSPMD
    # inserts the all-gather before attention/MLP and reduce-scatter after).
    # Without this, scan saves 61 full (B_loc, S, D) carries per device.
    "act_seq": "model",
    "act_embed": "model",       # residual-stream d_model sharding (alt.)
    "act_vocab": "model",
    # attention activations (q/k/v/scores); defaults fit archs whose head
    # counts divide the 16-way model axis — others override act_q_seq
    # (context parallelism) or rely on divisibility auto-drop.
    "act_q_seq": None,
    "act_kv_seq": None,
    "act_heads": "model",
    "act_kv_heads": "model",
    "act_head_dim": None,
    "edges": ("pod", "data"),
    "nodes": None,
    "candidates": ("pod", "data"),
    # batch over EVERY axis — for ops whose weight dims can't shard (e.g.
    # xDeepFM's 200 CIN filters vs the 16-way model axis)
    "act_all_batch": ("pod", "data", "model"),
    # weights
    "fsdp": ("pod", "data"),
    "embed": None,
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "mlp": "model",
    "expert": "model",
    "expert_mlp": None,
    "table_rows": "model",
    "table_dim": None,
    "layers": None,
    "stages": None,
    # KV cache
    "cache_batch": ("pod", "data"),
    "cache_seq": None,
    "cache_kv_heads": "model",
    "cache_head_dim": None,
}


def make_rules(overrides: Optional[Mapping[str, Axis]] = None) -> dict[str, Axis]:
    rules = dict(DEFAULT_RULES)
    if overrides:
        rules.update(overrides)
    return rules


def _filter_axis(axis: Axis, mesh_axis_names: Sequence[str]) -> Axis:
    """Drop mesh axes that don't exist on this mesh (e.g. 'pod' on 1-pod)."""
    if axis is None:
        return None
    if isinstance(axis, str):
        return axis if axis in mesh_axis_names else None
    kept = tuple(a for a in axis if a in mesh_axis_names)
    if not kept:
        return None
    return kept if len(kept) > 1 else kept[0]


def to_pspec(logical: Sequence[Optional[str]], rules: Mapping[str, Axis],
             mesh_axis_names: Sequence[str]) -> P:
    """Map a tuple of logical axis names to a PartitionSpec."""
    out, used = [], set()
    for name in logical:
        if name is None:
            out.append(None)
            continue
        axis = _filter_axis(rules.get(name), mesh_axis_names)
        # A mesh axis may be used at most once per spec; later dims lose.
        if axis is None:
            out.append(None)
        elif isinstance(axis, str):
            if axis in used:
                out.append(None)
            else:
                used.add(axis)
                out.append(axis)
        else:
            kept = tuple(a for a in axis if a not in used)
            if not kept:
                out.append(None)
            else:
                used.update(kept)
                out.append(kept if len(kept) > 1 else kept[0])
    return P(*out)


def pspec_tree(logical_tree, rules, mesh_axis_names):
    """Same-structure pytree of PartitionSpecs from logical-axis tuples.

    Leaves of `logical_tree` are tuples/lists of logical names (or None).
    """
    is_leaf = lambda x: isinstance(x, (tuple, list)) and all(
        isinstance(e, (str, type(None))) for e in x
    )
    return jax.tree_util.tree_map(
        lambda lg: to_pspec(lg, rules, mesh_axis_names), logical_tree,
        is_leaf=is_leaf,
    )


def sharding_tree(pspec_tree_, mesh: Mesh):
    return jax.tree_util.tree_map(
        lambda spec: NamedSharding(mesh, spec), pspec_tree_,
        is_leaf=lambda x: isinstance(x, P),
    )


def constrain(x, logical, rules, mesh_axis_names):
    """with_sharding_constraint via logical names. No-op outside jit-mesh."""
    spec = to_pspec(logical, rules, mesh_axis_names)
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError):
        return x  # no mesh in scope (e.g. CPU smoke tests)


# Logical dims that are "data-like": sharding them when the dim is smaller
# than the mesh axis product would pad (batch=1 over 32 devices) — drop.
DATA_DIMS = frozenset({"batch", "cache_batch", "candidates", "edges"})


def axes_prod(axis: Axis, mesh) -> int:
    if axis is None:
        return 1
    axes = (axis,) if isinstance(axis, str) else axis
    n = 1
    for a in axes:
        n *= mesh.shape.get(a, 1)
    return n


def sanitized_pspec(shape, logical, rules, mesh) -> P:
    """PartitionSpec for a concrete shape.

    Universal rule: a dim is sharded iff it divides the mesh-axis product
    (never padded, never uneven — jit in_shardings reject uneven anyway),
    and each mesh axis is used at most once per spec. Non-divisible dims
    replicate; the per-arch sharding_overrides are designed so that every
    tensor that MATTERS divides cleanly (DESIGN.md §5).
    """
    names = mesh.axis_names
    out, used = [], set()
    for dim, name in zip(shape, tuple(logical)):
        axis = None
        if name is not None:
            axis = _filter_axis(rules.get(name), names)
        if axis is not None:
            ax_tuple = (axis,) if isinstance(axis, str) else tuple(axis)
            if any(a in used for a in ax_tuple):
                axis = None
            elif dim % axes_prod(axis, mesh) != 0:
                axis = None
            else:
                used.update(ax_tuple)
        out.append(axis)
    return P(*out)


class ShardCtx:
    """Carries (mesh, rules) through model code for activation constraints.

    A no-arg ShardCtx() is a no-op — CPU smoke tests and pure-function unit
    tests run model code unchanged.
    """

    def __init__(self, mesh=None, rules: Optional[Mapping[str, Axis]] = None):
        self.mesh = mesh
        self.rules = dict(rules) if rules else dict(DEFAULT_RULES)

    def cs(self, x, *logical):
        if self.mesh is None:
            return x
        spec = sanitized_pspec(x.shape, logical, self.rules, self.mesh)
        return jax.lax.with_sharding_constraint(x, spec)

