"""Pytree utilities shared across the framework.

We deliberately avoid flax/optax: parameters are plain nested dicts of
jnp arrays, and these helpers provide the small amount of structure we
need (counting, dtype casting, RNG fan-out, global-norm).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def param_count(tree) -> int:
    """Total number of scalar parameters in a pytree."""
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(tree))


def param_bytes(tree) -> int:
    """Total bytes of a pytree (per-leaf dtype-aware)."""
    return sum(
        int(np.prod(x.shape)) * x.dtype.itemsize
        for x in jax.tree_util.tree_leaves(tree)
    )


def cast_tree(tree, dtype):
    """Cast every floating leaf of a pytree to `dtype` (ints untouched)."""
    def _cast(x):
        if jnp.issubdtype(x.dtype, jnp.floating):
            return x.astype(dtype)
        return x
    return jax.tree_util.tree_map(_cast, tree)


def tree_zeros_like(tree, dtype=None):
    return jax.tree_util.tree_map(
        lambda x: jnp.zeros(x.shape, dtype or x.dtype), tree
    )


def _sumsq(x) -> jax.Array:
    """sum(x^2) in fp32 without materializing a full f32 copy.

    XLA CPU fails to fuse convert+square+reduce on multi-GiB bf16 tensors
    (observed 3 x 5 GiB f32 temp on the 1T-param config); chunking the
    reduction over axis 0 bounds the transient at one slice.
    """
    if x.ndim >= 2 and x.size > (1 << 26):
        def body(acc, sl):
            return acc + jnp.sum(jnp.square(sl.astype(jnp.float32))), None
        acc, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), x)
        return acc
    return jnp.sum(jnp.square(x.astype(jnp.float32)))


def global_norm(tree) -> jax.Array:
    """L2 norm over every leaf of a pytree (fp32 accumulation)."""
    leaves = [_sumsq(x) for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def split_rng_tree(rng, tree):
    """One independent RNG key per leaf, packaged in the same structure."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    keys = jax.random.split(rng, len(leaves))
    return jax.tree_util.tree_unflatten(treedef, list(keys))


def tree_map_with_path_names(fn, tree):
    """tree_map where fn also receives a '/'-joined string path."""
    def _wrap(path, leaf):
        name = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        return fn(name, leaf)
    return jax.tree_util.tree_map_with_path(_wrap, tree)
